//! Heterogeneous multi-environment placement optimizer (DESIGN.md §12).
//!
//! The paper's headline result is not "a cluster" but a *heterogeneous*
//! fleet: low-cost HPC slots plus cloud burst plus local workstations,
//! chosen per workload, reaching ~20× cost-effectiveness at comparable
//! makespan (PAPER §4, Table 1). Until this module, the cost/speed
//! tradeoff was answered by the static `cost::planner` projection —
//! campaigns could only co-simulate against one backend at a time.
//!
//! Here one campaign is **split across several simultaneously
//! co-simulated backends** ([`super::staged::run_multi`]): each
//! [`BackendSpec`] owns its compute engine (the SLURM simulator or a
//! lane pool), its `$`/hr slot rate ([`crate::cost::instance_hourly_rate`]),
//! its environment speed factor, and optionally its own
//! [`crate::faults::Injection`] — while **every backend shares one
//! [`TransferScheduler`]**. Each backend is a host on the shared
//! staging path, so cloud's faster per-job compute re-contends for the
//! same storage egress the paper measured (0.60 Gb/s HPC-side vs
//! 0.33 Gb/s WAN-side composite): the shared path's per-host stream
//! caps ([`Topology::with_host_stream_cap`]) model each backend's
//! admission width, and the bottleneck link is divided max-min fairly
//! across all of them.
//!
//! Three policies assign jobs to backends ([`PlacementPolicy`]):
//!
//! * [`PlacementPolicy::CheapestFirst`] — every job to the backend with
//!   the lowest projected per-job dollars;
//! * [`PlacementPolicy::DeadlineAware`] — prefer the cheapest backend,
//!   bursting a job to faster/wider backends only when the release
//!   skyline (the planning-time analogue of the SLURM EASY
//!   release-skyline estimate) predicts a deadline miss;
//! * [`PlacementPolicy::BudgetCapped`] — minimize projected finish
//!   subject to projected spend staying under a dollar budget.
//!
//! [`frontier_sweep`] generalizes `benches/fig1_tradeoff.rs` from two
//! fixed points to a full curve: all-one-backend anchors plus a
//! deadline sweep, pruned to the Pareto set ([`pareto`] — no emitted
//! point is dominated on (cost, makespan)).
//!
//! Everything is deterministic given the seed: assignments are pure
//! functions of the plan inputs, and every engine samples from
//! per-(id, attempt) streams — `benches/placement_frontier.rs` and
//! `rust/tests/placement_parity.rs` gate determinism, the policy
//! invariants, and single-backend parity with [`super::staged`].

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::compute::env_speed_factor;
use crate::cost::{compute_cost, instance_hourly_rate, staged_job_cost};
use crate::faults::outage::{OutageSchedule, OutageStats, OutageWindow};
use crate::faults::{FaultEvent, FaultModel, Injection};
use crate::netsim::scheduler::{Topology, TransferScheduler, TransferStats};
use crate::netsim::Env;
use crate::slurm::{ArrayHandle, ClusterSpec, Scheduler};
use crate::util::ord::F64Ord;
use crate::util::units::{fmt_duration, gbps_to_bytes_per_sec};

use super::spec::RunSpec;
use super::staged::{run_multi_impl, ComputeSim, LanePool, SlurmSim, StagedJob, StagedOutcome};

/// Salt decorrelating the shared staging path's per-transfer sampling
/// from the campaign/faults streams ("placxfr").
pub const PLACEMENT_TRANSFER_SALT: u64 = 0x706c_6163_7866_7231;

/// The compute substrate behind one placement backend.
#[derive(Debug, Clone)]
pub enum BackendKind {
    /// A SLURM cluster (the HPC path): fairshare, backfill, array
    /// throttle — the full [`Scheduler`] co-simulation.
    Slurm {
        cluster: ClusterSpec,
        max_concurrent: u32,
    },
    /// A bounded pool of identical lanes ([`LanePool`]): the cloud
    /// instance pool or the local-workstation burst path.
    Lanes { workers: usize },
}

/// One backend of a heterogeneous placement fleet.
#[derive(Debug, Clone)]
pub struct BackendSpec {
    pub name: String,
    /// Slot pricing ([`instance_hourly_rate`]), compute speed
    /// ([`env_speed_factor`]) — the Table 1 column this backend plays.
    pub env: Env,
    pub kind: BackendKind,
    /// Failure model injected into this backend's compute engine
    /// (compute bands with timeout parking, per-backend decorrelated —
    /// [`Injection::placement_compute`]); `None` = clean backend.
    pub faults: Option<FaultModel>,
    /// Concurrent transfer streams this backend's host may hold open on
    /// the shared staging path.
    pub transfer_streams: usize,
}

impl BackendSpec {
    /// $/hour to hold one job slot here.
    pub fn hourly_rate(&self) -> f64 {
        instance_hourly_rate(self.env)
    }

    /// Wall-clock of `job` once started on this backend (the Table 1
    /// environment speed difference, exact for `Env::Hpc`: factor 1).
    pub fn effective_compute_s(&self, job: &StagedJob) -> f64 {
        job.compute_s / env_speed_factor(self.env)
    }

    /// Concurrent job slots this backend offers to jobs of the given
    /// shape — the release-skyline width.
    pub fn slots(&self, cores: u32, ram_gb: u32) -> u64 {
        match &self.kind {
            BackendKind::Lanes { workers } => (*workers).max(1) as u64,
            BackendKind::Slurm {
                cluster,
                max_concurrent,
            } => cluster
                .concurrent_slots(cores, ram_gb)
                .min(u64::from(*max_concurrent)),
        }
    }
}

/// The paper's fleet (§4, Table 1): the coordinator's HPC cluster, an
/// AWS-style cloud lane pool, and local workstations. Fault models
/// default to `None`; callers inject per-backend models as needed.
pub fn default_fleet(
    cluster: ClusterSpec,
    max_concurrent: u32,
    cloud_lanes: usize,
    local_lanes: usize,
) -> Vec<BackendSpec> {
    vec![
        BackendSpec {
            name: "hpc".into(),
            env: Env::Hpc,
            kind: BackendKind::Slurm {
                cluster,
                max_concurrent,
            },
            faults: None,
            transfer_streams: 8,
        },
        BackendSpec {
            name: "cloud".into(),
            env: Env::Cloud,
            kind: BackendKind::Lanes {
                workers: cloud_lanes,
            },
            faults: None,
            transfer_streams: 4,
        },
        BackendSpec {
            name: "local".into(),
            env: Env::Local,
            kind: BackendKind::Lanes {
                workers: local_lanes,
            },
            faults: None,
            transfer_streams: 2,
        },
    ]
}

/// Co-simulation knobs shared by every placement run.
#[derive(Debug, Clone, Copy)]
pub struct PlacementConfig {
    pub seed: u64,
    /// Failure model whose checksum band is injected into the shared
    /// staging path ([`Injection::campaign_transfer`] split); `None` =
    /// clean transfers.
    pub transfer_faults: Option<FaultModel>,
    pub max_retries: u32,
    pub retry_backoff_s: f64,
}

impl Default for PlacementConfig {
    fn default() -> Self {
        Self {
            seed: 42,
            transfer_faults: None,
            max_retries: 3,
            retry_backoff_s: 60.0,
        }
    }
}

/// The shared staging path every backend contends on: the archive's
/// storage-side composite (the paper's §4 point — the HDD store, not
/// the 100 Gb fabric, binds the HPC path), with each backend's own
/// per-host stream cap (host id = backend index). Per-stream ceilings
/// sample from the storage-side profile for every host; the per-backend
/// last-mile differences are absorbed into the composite.
pub fn shared_topology(fleet: &[BackendSpec]) -> Topology {
    let mut topo = Topology::of(Env::Hpc);
    if let [only] = fleet {
        // a single-backend fleet is the uniform-cap special case: set
        // the global cap too, so the frozen `sim_legacy` engine (which
        // predates per-host overrides and reads the uniform cap) stays
        // comparable on the parity gates
        topo = topo.with_stream_cap(only.transfer_streams.max(1));
    }
    for (k, b) in fleet.iter().enumerate() {
        topo = topo.with_host_stream_cap(k as u64, b.transfer_streams.max(1));
    }
    topo
}

/// How a campaign's jobs are assigned to fleet backends.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PlacementPolicy {
    /// Every job to the backend with the lowest projected per-job
    /// dollars (slot rate × effective duration; ties favor the lower
    /// hourly rate). Degenerates to all-HPC at the paper's rates.
    CheapestFirst,
    /// Prefer cheaper backends; burst a job to a faster/wider backend
    /// only when the release skyline predicts it would finish after
    /// `deadline_s` everywhere cheaper. With no predicted miss this is
    /// exactly [`PlacementPolicy::CheapestFirst`].
    DeadlineAware { deadline_s: f64 },
    /// Minimize each job's projected finish subject to cumulative
    /// projected spend ≤ `budget_dollars`; once the budget is committed,
    /// jobs fall back to the cheapest backend.
    BudgetCapped { budget_dollars: f64 },
    /// Every job to the named fleet backend — the frontier sweep's
    /// all-one-backend anchors and the single-backend parity gate, not
    /// an optimizer.
    Pinned(usize),
}

impl PlacementPolicy {
    pub fn label(&self) -> String {
        match self {
            PlacementPolicy::CheapestFirst => "cheapest-first".into(),
            PlacementPolicy::DeadlineAware { deadline_s } => {
                format!("deadline-aware ≤ {}", fmt_duration(*deadline_s))
            }
            PlacementPolicy::BudgetCapped { budget_dollars } => {
                format!("budget-capped ≤ ${budget_dollars:.2}")
            }
            PlacementPolicy::Pinned(k) => format!("pinned to backend {k}"),
        }
    }
}

/// Placement-time release skyline of one backend: per-slot busy-until
/// times in a min-heap — the planning analogue of the in-engine EASY
/// release skyline (`slurm::Scheduler`'s earliest-start estimate). A
/// job lands on the earliest-releasing slot; its projected finish
/// becomes that slot's next release.
struct Skyline {
    free: BinaryHeap<Reverse<F64Ord>>,
}

/// Skyline heaps are capped: beyond this many slots the backend is
/// never the projected constraint for any in-tree campaign size.
const SKYLINE_SLOT_CAP: u64 = 1 << 20;

impl Skyline {
    fn new(slots: u64) -> Self {
        let slots = slots.clamp(1, SKYLINE_SLOT_CAP) as usize;
        Self {
            free: (0..slots).map(|_| Reverse(F64Ord(0.0))).collect(),
        }
    }

    fn earliest_start(&self) -> f64 {
        self.free.peek().map_or(0.0, |Reverse(t)| t.0)
    }

    /// Commit a job of `dur` seconds to the earliest slot; returns its
    /// projected finish.
    fn commit(&mut self, dur: f64) -> f64 {
        let Reverse(F64Ord(start)) = self.free.pop().expect("skyline holds ≥ 1 slot");
        let finish = start + dur;
        self.free.push(Reverse(F64Ord(finish)));
        finish
    }
}

/// Planner's stage-in + copy-back estimate: the job's bytes across the
/// shared path's bottleneck at full rate. Optimistic under contention,
/// but uniformly so across backends — which is all the ranking needs;
/// the co-simulation is the measurement.
pub(crate) fn transfer_estimate_s(job: &StagedJob, bottleneck_gbps: f64) -> f64 {
    (job.bytes_in + job.bytes_out) as f64 / gbps_to_bytes_per_sec(bottleneck_gbps)
}

/// Fleet indices in "cheapest" order: $/hr ascending, index-stable —
/// the tie-break every policy (and the outage re-placement rule) uses.
pub(crate) fn rate_order(fleet: &[BackendSpec]) -> Vec<usize> {
    let mut by_rate: Vec<usize> = (0..fleet.len()).collect();
    by_rate.sort_by(|&a, &b| {
        F64Ord(fleet[a].hourly_rate())
            .cmp(&F64Ord(fleet[b].hourly_rate()))
            .then(a.cmp(&b))
    });
    by_rate
}

/// A deterministic job→backend assignment plus the planner's
/// projections (estimates; [`execute`]'s co-simulation measures).
#[derive(Debug, Clone)]
pub struct PlacementPlan {
    pub policy: PlacementPolicy,
    /// Job index → backend index.
    pub assignment: Vec<usize>,
    /// The campaign with each job's compute scaled to its assigned
    /// backend's speed — what the co-simulation runs.
    pub effective: Vec<StagedJob>,
    pub projected_cost_dollars: f64,
    pub projected_makespan_s: f64,
}

/// Assign every job to a backend under `policy` (pure, deterministic:
/// no sampling — the engines sample, the planner only projects).
pub fn plan(jobs: &[StagedJob], fleet: &[BackendSpec], policy: PlacementPolicy) -> PlacementPlan {
    assert!(!fleet.is_empty(), "placement needs at least one backend");
    if let PlacementPolicy::Pinned(k) = policy {
        assert!(k < fleet.len(), "pinned backend {k} of {}", fleet.len());
    }
    // skylines sized by each backend's width for the campaign's lead
    // job shape (synthetic campaigns are shape-uniform; heterogeneous
    // shapes only blur the estimate — the engines enforce real packing)
    let shape = jobs.first().map_or((1, 0), |j| (j.cores, j.ram_gb));
    let mut skylines: Vec<Skyline> = fleet
        .iter()
        .map(|b| Skyline::new(b.slots(shape.0, shape.1)))
        .collect();
    let bottleneck_gbps = shared_topology(fleet).bottleneck_gbps();
    let by_rate = rate_order(fleet);

    let mut assignment = Vec::with_capacity(jobs.len());
    let mut spent = 0.0f64;
    let mut projected_makespan = 0.0f64;
    for job in jobs {
        let xfer_s = transfer_estimate_s(job, bottleneck_gbps);
        // (projected finish, projected dollars) per backend
        let cand: Vec<(f64, f64)> = fleet
            .iter()
            .enumerate()
            .map(|(k, b)| {
                let eff = b.effective_compute_s(job);
                let finish = skylines[k].earliest_start() + xfer_s + eff;
                (finish, staged_job_cost(b.env, eff / 60.0, xfer_s))
            })
            .collect();
        let fastest = |ks: &[usize]| -> usize {
            *ks.iter()
                .min_by(|&&a, &&b| F64Ord(cand[a].0).cmp(&F64Ord(cand[b].0)))
                .expect("non-empty candidate set")
        };
        let pick = match policy {
            PlacementPolicy::Pinned(k) => k,
            PlacementPolicy::CheapestFirst => *by_rate
                .iter()
                .min_by(|&&a, &&b| F64Ord(cand[a].1).cmp(&F64Ord(cand[b].1)))
                .expect("non-empty fleet"),
            PlacementPolicy::DeadlineAware { deadline_s } => by_rate
                .iter()
                .copied()
                .find(|&k| cand[k].0 <= deadline_s)
                .unwrap_or_else(|| fastest(&by_rate)),
            PlacementPolicy::BudgetCapped { budget_dollars } => {
                let allowed: Vec<usize> = by_rate
                    .iter()
                    .copied()
                    .filter(|&k| spent + cand[k].1 <= budget_dollars)
                    .collect();
                if allowed.is_empty() {
                    by_rate[0] // budget gone: cheapest damage
                } else {
                    fastest(&allowed)
                }
            }
        };
        let eff = fleet[pick].effective_compute_s(job);
        let finish = skylines[pick].commit(xfer_s + eff);
        spent += cand[pick].1;
        projected_makespan = projected_makespan.max(finish);
        assignment.push(pick);
    }
    let effective = jobs
        .iter()
        .zip(&assignment)
        .map(|(j, &k)| StagedJob {
            compute_s: fleet[k].effective_compute_s(j),
            ..*j
        })
        .collect();
    PlacementPlan {
        policy,
        assignment,
        effective,
        projected_cost_dollars: spent,
        projected_makespan_s: projected_makespan,
    }
}

/// One backend's live engine (kept alive past the windowed run so fault
/// telemetry can be drained). Shared with [`super::tenancy`], whose
/// N=1 parity gate depends on constructing engines through the exact
/// same path as [`run_plan_chaos`].
pub(crate) enum BackendEngine {
    Slurm(SlurmSim),
    Lanes(LanePool),
}

impl BackendEngine {
    pub(crate) fn as_compute(&mut self) -> &mut dyn ComputeSim {
        match self {
            BackendEngine::Slurm(s) => s,
            BackendEngine::Lanes(l) => l,
        }
    }

    /// `ComputeSim::next_event_time` without taking `&mut self` — the
    /// tenancy loop re-arms its event heap while also reading abort
    /// counters, so it cannot hold `as_compute` borrows across the
    /// iteration the way `run_multi`'s `&mut dyn` slice does.
    pub(crate) fn peek_next_event(&self) -> Option<f64> {
        match self {
            BackendEngine::Slurm(s) => s.next_event_time(),
            BackendEngine::Lanes(l) => l.next_event_time(),
        }
    }

    pub(crate) fn fault_events(&self) -> &[FaultEvent] {
        match self {
            BackendEngine::Slurm(s) => s.scheduler().fault_events(),
            BackendEngine::Lanes(l) => l.fault_events(),
        }
    }

    pub(crate) fn aborted_count(&self) -> usize {
        match self {
            BackendEngine::Slurm(s) => s.scheduler().aborted_ids().len(),
            BackendEngine::Lanes(l) => l.aborted_ids().len(),
        }
    }

    /// Install this backend's outage windows (DESIGN.md §15) — must
    /// precede all submissions, like the underlying engines require.
    pub(crate) fn set_outages(&mut self, windows: Vec<OutageWindow>, kill_backoff_s: f64) {
        match self {
            BackendEngine::Slurm(s) => s.scheduler_mut().set_outages(windows, kill_backoff_s),
            BackendEngine::Lanes(l) => l.set_outages(windows, kill_backoff_s),
        }
    }

    pub(crate) fn outage_killed(&self) -> u64 {
        match self {
            BackendEngine::Slurm(s) => s.scheduler().outage_killed(),
            BackendEngine::Lanes(l) => l.outage_killed(),
        }
    }

    pub(crate) fn outage_wasted_s(&self) -> f64 {
        match self {
            BackendEngine::Slurm(s) => s.scheduler().outage_wasted_s(),
            BackendEngine::Lanes(l) => l.outage_wasted_s(),
        }
    }
}

pub(crate) fn build_engine(
    spec: &BackendSpec,
    backend: usize,
    cfg: &PlacementConfig,
) -> BackendEngine {
    let inj = spec.faults.map(|m| {
        Injection::placement_compute(&m, cfg.max_retries, cfg.seed, backend, cfg.retry_backoff_s)
    });
    match &spec.kind {
        BackendKind::Slurm {
            cluster,
            max_concurrent,
        } => {
            let mut sched = Scheduler::new(cluster.clone());
            if let Some(inj) = inj {
                sched.set_faults(inj);
            }
            let handle = ArrayHandle {
                array_id: 1 + backend as u64,
                max_concurrent: *max_concurrent,
            };
            BackendEngine::Slurm(SlurmSim::new(sched, "medflow", Some(handle)))
        }
        BackendKind::Lanes { workers } => {
            let mut lanes = LanePool::new((*workers).max(1));
            if let Some(inj) = inj {
                lanes.set_faults(inj);
            }
            BackendEngine::Lanes(lanes)
        }
    }
}

/// One backend's measured share of a placement run.
#[derive(Debug, Clone, PartialEq)]
pub struct BackendUsage {
    pub name: String,
    pub env: Env,
    /// Jobs the plan assigned here.
    pub jobs: usize,
    /// Jobs that reached a verified copy-back.
    pub completed: usize,
    /// Effective compute minutes billed (wasted failed attempts
    /// included — the §4 overrun, itemized per backend).
    pub compute_minutes: f64,
    pub cost_dollars: f64,
    /// Failed attempts this backend's engine recorded.
    pub failed_attempts: usize,
    pub aborted: usize,
}

/// Result of co-simulating one placement.
#[derive(Debug)]
pub struct PlacementOutcome {
    pub plan: PlacementPlan,
    pub staged: StagedOutcome,
    pub per_backend: Vec<BackendUsage>,
    pub total_cost_dollars: f64,
    pub makespan_s: f64,
    pub transfer: TransferStats,
    /// Every backend's compute-fault events, concatenated in backend
    /// order (ids are job indices).
    pub compute_events: Vec<FaultEvent>,
    /// Shared staging path checksum failures (ids are transfer ids).
    pub transfer_events: Vec<FaultEvent>,
    /// Jobs + transfers dropped after exhausting retries, fleet-wide.
    pub aborted: u64,
    /// Infrastructure-outage telemetry (DESIGN.md §15): `Some` exactly
    /// when the run went through [`execute_chaos`] — the chaos-free
    /// path never constructs it.
    pub outage: Option<OutageStats>,
}

/// Plan under `policy`, then co-simulate the fleet (every backend's
/// engine advancing in lockstep against the shared staging path) and
/// fold per-backend cost at each environment's slot rate.
#[deprecated(
    since = "0.1.0",
    note = "compose a coordinator::RunSpec with .policy(p) and call RunSpec::execute"
)]
pub fn execute(
    jobs: &[StagedJob],
    fleet: &[BackendSpec],
    policy: PlacementPolicy,
    cfg: &PlacementConfig,
) -> PlacementOutcome {
    RunSpec::new().policy(policy).execute(jobs, fleet, cfg)
}

/// [`execute`] with the fleet's engines sharded across `threads` worker
/// threads under conservative time-window sync (DESIGN.md §16). Any
/// thread count is f64-record-identical to [`execute`]
/// (`rust/tests/parallel_parity.rs`).
#[deprecated(
    since = "0.1.0",
    note = "compose a coordinator::RunSpec with .policy(p).threads(n) and call RunSpec::execute"
)]
pub fn execute_threaded(
    jobs: &[StagedJob],
    fleet: &[BackendSpec],
    policy: PlacementPolicy,
    cfg: &PlacementConfig,
    threads: usize,
) -> PlacementOutcome {
    RunSpec::new().policy(policy).threads(threads).execute(jobs, fleet, cfg)
}

/// [`execute`] under an infrastructure-fault schedule (DESIGN.md §15):
/// each backend's outage windows go to its engine, the shared staging
/// path gets the schedule's brownouts, and every job orphaned at an
/// onset is **re-placed** — onto the cheapest backend not inside a
/// window at the orphan instant (rate order, index-stable; the
/// original backend if none survives), its compute rescaled to the new
/// backend's speed, its inputs re-staged over the degraded path. With
/// an empty schedule the engine-call sequence is identical to
/// [`execute`], so the outcome is f64-record-identical
/// (`rust/tests/chaos_cosim.rs`); panics if the schedule fails
/// [`OutageSchedule::validate`].
#[deprecated(
    since = "0.1.0",
    note = "compose a coordinator::RunSpec with .policy(p).outages(s) and call RunSpec::execute"
)]
pub fn execute_chaos(
    jobs: &[StagedJob],
    fleet: &[BackendSpec],
    policy: PlacementPolicy,
    cfg: &PlacementConfig,
    schedule: &OutageSchedule,
) -> PlacementOutcome {
    RunSpec::new().policy(policy).outages(schedule.clone()).execute(jobs, fleet, cfg)
}

/// [`execute_chaos`] on `threads` engine workers — outage onsets,
/// orphan re-placement, and brownouts all ride the same windowed
/// protocol, so chaos runs too are f64-record-identical at any thread
/// count (`rust/tests/chaos_cosim.rs` + `parallel_parity.rs`).
#[deprecated(
    since = "0.1.0",
    note = "compose a coordinator::RunSpec with .policy(p).outages(s).threads(n) and call RunSpec::execute"
)]
pub fn execute_chaos_threaded(
    jobs: &[StagedJob],
    fleet: &[BackendSpec],
    policy: PlacementPolicy,
    cfg: &PlacementConfig,
    schedule: &OutageSchedule,
    threads: usize,
) -> PlacementOutcome {
    RunSpec::new()
        .policy(policy)
        .outages(schedule.clone())
        .threads(threads)
        .execute(jobs, fleet, cfg)
}

/// [`execute`] with every job pinned to one backend — the frontier's
/// anchors and the parity gate against the single-backend staged path.
#[deprecated(
    since = "0.1.0",
    note = "compose a coordinator::RunSpec with .policy(PlacementPolicy::Pinned(k)) and call RunSpec::execute"
)]
pub fn execute_pinned(
    jobs: &[StagedJob],
    fleet: &[BackendSpec],
    backend: usize,
    cfg: &PlacementConfig,
) -> PlacementOutcome {
    RunSpec::new().policy(PlacementPolicy::Pinned(backend)).execute(jobs, fleet, cfg)
}

/// The per-job billing rule shared by placement and tenancy (the one
/// definition both folds price with — `coordinator::tenancy`'s N=1
/// parity gate would catch any drift between two copies).
///
/// Returns `(billed_minutes, dollars)`: a completed job pays its
/// effective compute plus wasted failed attempts plus contended wire
/// time at the backend's rate; a dropped job pays its wasted attempts
/// as real spend, plus the full nominal allocation when compute itself
/// finished (a post-compute abort) — the `dropped_attempt_cost` rule.
pub(crate) fn job_billing(
    env: Env,
    effective_compute_s: f64,
    wasted_min: f64,
    t: &super::staged::StagedTiming,
) -> (f64, f64) {
    if t.completed {
        let eff_min = effective_compute_s / 60.0 + wasted_min;
        (eff_min, staged_job_cost(env, eff_min, t.stage_in_s + t.stage_out_s))
    } else {
        let mut lost_min = wasted_min;
        if t.compute_end_s > 0.0 {
            lost_min += effective_compute_s / 60.0;
        }
        (lost_min, compute_cost(env, lost_min))
    }
}

/// Drain every engine's compute-fault telemetry: per-job wasted
/// allocation minutes (compute ids are job indices) plus all events
/// concatenated in backend order.
pub(crate) fn collect_compute_faults(
    engines: &[BackendEngine],
    n_jobs: usize,
) -> (Vec<f64>, Vec<FaultEvent>) {
    let mut wasted_min = vec![0.0f64; n_jobs];
    let mut compute_events = Vec::new();
    for engine in engines {
        for ev in engine.fault_events() {
            if let Some(w) = wasted_min.get_mut(ev.id as usize) {
                *w += ev.wasted_s / 60.0;
            }
            compute_events.push(*ev);
        }
    }
    (wasted_min, compute_events)
}

/// Fold the co-simulated timings into per-backend usage rows (jobs,
/// completions, billed minutes, dollars, fault counters) — in global
/// job order, so the f64 accumulation order is identical wherever the
/// fold runs.
pub(crate) fn fold_backend_usage(
    fleet: &[BackendSpec],
    effective: &[StagedJob],
    assignment: &[usize],
    timings: &[super::staged::StagedTiming],
    wasted_min: &[f64],
    engines: &[BackendEngine],
) -> Vec<BackendUsage> {
    let mut per_backend: Vec<BackendUsage> = fleet
        .iter()
        .map(|b| BackendUsage {
            name: b.name.clone(),
            env: b.env,
            jobs: 0,
            completed: 0,
            compute_minutes: 0.0,
            cost_dollars: 0.0,
            failed_attempts: 0,
            aborted: 0,
        })
        .collect();
    for (i, (&k, t)) in assignment.iter().zip(timings).enumerate() {
        let usage = &mut per_backend[k];
        usage.jobs += 1;
        if t.completed {
            usage.completed += 1;
        }
        let (minutes, dollars) = job_billing(fleet[k].env, effective[i].compute_s, wasted_min[i], t);
        usage.compute_minutes += minutes;
        usage.cost_dollars += dollars;
    }
    for (k, engine) in engines.iter().enumerate() {
        per_backend[k].failed_attempts = engine.fault_events().len();
        per_backend[k].aborted = engine.aborted_count();
    }
    per_backend
}

/// The one placement funnel every entry point drains into
/// ([`crate::coordinator::RunSpec::execute`] and, through it, the
/// deprecated `execute*` shims).
pub(crate) fn run_plan_chaos(
    fleet: &[BackendSpec],
    plan: PlacementPlan,
    cfg: &PlacementConfig,
    schedule: Option<&OutageSchedule>,
    threads: usize,
) -> PlacementOutcome {
    let mut engines: Vec<BackendEngine> = fleet
        .iter()
        .enumerate()
        .map(|(k, b)| build_engine(b, k, cfg))
        .collect();
    let mut transfers =
        TransferScheduler::new(shared_topology(fleet), cfg.seed ^ PLACEMENT_TRANSFER_SALT);
    if let Some(m) = cfg.transfer_faults {
        transfers.set_faults(Injection::campaign_transfer(&m, cfg.max_retries, cfg.seed));
    }
    if let Some(s) = schedule {
        transfers.set_brownouts(s.brownouts.clone());
        for (k, engine) in engines.iter_mut().enumerate() {
            engine.set_outages(s.windows_for(k), s.kill_backoff_s);
        }
    }
    // re-placement rule: cheapest backend alive at the orphan instant
    // (rate order, index-stable), the original backend when none is;
    // compute rescales to the new backend's speed via the job's nominal
    // duration (recovered from its planned backend's factor)
    let by_rate = rate_order(fleet);
    let planned: Vec<usize> = plan.assignment.clone();
    let planned_eff: Vec<StagedJob> = plan.effective.clone();
    let (staged, chaos) = {
        let mut backends: Vec<&mut dyn ComputeSim> =
            engines.iter_mut().map(|e| e.as_compute()).collect();
        match schedule {
            None => run_multi_impl(
                &plan.effective,
                &plan.assignment,
                &mut backends,
                &mut transfers,
                None,
                threads,
            ),
            Some(s) => {
                let mut replace = |i: usize, t: f64, from: usize| {
                    let to = by_rate
                        .iter()
                        .copied()
                        .find(|&k| s.in_window(k, t).is_none())
                        .unwrap_or(from);
                    let nominal_s =
                        planned_eff[i].compute_s * env_speed_factor(fleet[planned[i]].env);
                    let job = StagedJob {
                        compute_s: nominal_s / env_speed_factor(fleet[to].env),
                        ..planned_eff[i]
                    };
                    (to, job)
                };
                run_multi_impl(
                    &plan.effective,
                    &plan.assignment,
                    &mut backends,
                    &mut transfers,
                    Some(&mut replace),
                    threads,
                )
            }
        }
    };
    let (wasted_min, compute_events) = collect_compute_faults(&engines, plan.effective.len());
    // fold against the FINAL placements: an orphan billed where it ran,
    // not where the plan put it (chaos-free, these equal the plan's)
    let per_backend = fold_backend_usage(
        fleet,
        &chaos.effective,
        &chaos.assignment,
        &staged.timings,
        &wasted_min,
        &engines,
    );
    let aborted = engines.iter().map(|e| e.aborted_count()).sum::<usize>()
        + transfers.aborted_ids().len();
    let outage = schedule.map(|s| OutageStats {
        windows: s.compute.len(),
        brownouts: s.brownouts.len(),
        killed: engines.iter().map(|e| e.outage_killed()).sum(),
        orphaned: chaos.orphaned,
        re_placed: chaos.re_placed,
        killed_wasted_s: engines.iter().map(|e| e.outage_wasted_s()).sum(),
    });
    PlacementOutcome {
        total_cost_dollars: per_backend.iter().map(|u| u.cost_dollars).sum(),
        makespan_s: staged.makespan_s,
        transfer: staged.transfer,
        per_backend,
        compute_events,
        transfer_events: transfers.fault_events().to_vec(),
        aborted: aborted as u64,
        outage,
        staged,
        plan,
    }
}

/// One placement on the cost-vs-makespan plane.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierPoint {
    pub label: String,
    pub cost_dollars: f64,
    pub makespan_s: f64,
    /// Jobs per backend, fleet order.
    pub jobs_per_backend: Vec<usize>,
}

fn frontier_point(label: String, fleet_len: usize, out: &PlacementOutcome) -> FrontierPoint {
    let mut jobs_per_backend = vec![0usize; fleet_len];
    for &k in &out.plan.assignment {
        jobs_per_backend[k] += 1;
    }
    FrontierPoint {
        label,
        cost_dollars: out.total_cost_dollars,
        makespan_s: out.makespan_s,
        jobs_per_backend,
    }
}

/// Sweep the cost-vs-makespan tradeoff — the full curve Fig. 1 only
/// showed two points of: co-simulate every all-one-backend anchor plus
/// `steps` deadline-aware placements with deadlines interpolated
/// strictly between the fastest and slowest anchor makespans, then
/// prune to the Pareto set ([`pareto`]).
pub fn frontier_sweep(
    jobs: &[StagedJob],
    fleet: &[BackendSpec],
    cfg: &PlacementConfig,
    steps: usize,
) -> Vec<FrontierPoint> {
    let mut points = Vec::with_capacity(fleet.len() + steps);
    let mut fastest = f64::INFINITY;
    let mut slowest = 0.0f64;
    for (k, backend) in fleet.iter().enumerate() {
        let out = RunSpec::new().policy(PlacementPolicy::Pinned(k)).execute(jobs, fleet, cfg);
        fastest = fastest.min(out.makespan_s);
        slowest = slowest.max(out.makespan_s);
        points.push(frontier_point(format!("all-{}", backend.name), fleet.len(), &out));
    }
    for s in 0..steps {
        let frac = (s as f64 + 1.0) / (steps as f64 + 1.0);
        let deadline_s = fastest + (slowest - fastest) * frac;
        let out = RunSpec::new()
            .policy(PlacementPolicy::DeadlineAware { deadline_s })
            .execute(jobs, fleet, cfg);
        points.push(frontier_point(
            format!("deadline {}", fmt_duration(deadline_s)),
            fleet.len(),
            &out,
        ));
    }
    pareto(points)
}

/// Prune to the Pareto frontier on (cost, makespan): sorted by cost,
/// a point survives only if its makespan strictly improves on every
/// cheaper (and every equal-cost, earlier-sorted) point; duplicates
/// collapse. The survivors are strictly increasing in cost and strictly
/// decreasing in makespan — no emitted point is dominated.
pub fn pareto(mut points: Vec<FrontierPoint>) -> Vec<FrontierPoint> {
    points.sort_by(|a, b| {
        (F64Ord(a.cost_dollars), F64Ord(a.makespan_s))
            .cmp(&(F64Ord(b.cost_dollars), F64Ord(b.makespan_s)))
    });
    let mut kept: Vec<FrontierPoint> = Vec::new();
    for p in points {
        // kept makespans are strictly decreasing, so the last is the
        // best seen — beating it beats every kept point
        if kept.last().is_none_or(|q| p.makespan_s < q.makespan_s) {
            kept.push(p);
        }
    }
    kept
}

#[cfg(test)]
// the unit tests deliberately exercise the deprecated shims: they are
// the compatibility surface the parity batteries pin
#[allow(deprecated)]
mod tests {
    use super::*;

    fn lanes(name: &str, env: Env, workers: usize) -> BackendSpec {
        BackendSpec {
            name: name.into(),
            env,
            kind: BackendKind::Lanes { workers },
            faults: None,
            transfer_streams: 4,
        }
    }

    fn jobs(n: usize, compute_s: f64) -> Vec<StagedJob> {
        (0..n)
            .map(|_| StagedJob {
                cores: 1,
                ram_gb: 1,
                compute_s,
                bytes_in: 20_000_000,
                bytes_out: 5_000_000,
            })
            .collect()
    }

    fn trio() -> Vec<BackendSpec> {
        vec![
            lanes("hpc", Env::Hpc, 2),
            lanes("cloud", Env::Cloud, 16),
            lanes("local", Env::Local, 1),
        ]
    }

    #[test]
    fn cheapest_first_places_everything_on_the_cheapest_rate() {
        let fleet = trio();
        // HPC is the cheapest $/hr by ~10× (Table 1)
        let p = plan(&jobs(20, 300.0), &fleet, PlacementPolicy::CheapestFirst);
        assert!(p.assignment.iter().all(|&k| k == 0), "{:?}", p.assignment);
        assert!(p.projected_cost_dollars > 0.0);
        // effective durations keep the assigned backend's speed: HPC = 1.0
        assert!(p.effective.iter().all(|j| j.compute_s == 300.0));
    }

    #[test]
    fn deadline_bursts_only_on_predicted_miss() {
        let fleet = trio(); // hpc has 2 lanes: serializes 10 × 600 s
        let js = jobs(10, 600.0);
        let loose = plan(&js, &fleet, PlacementPolicy::DeadlineAware { deadline_s: 1e9 });
        assert!(loose.assignment.iter().all(|&k| k == 0), "no miss, no burst");

        let tight = plan(&js, &fleet, PlacementPolicy::DeadlineAware { deadline_s: 700.0 });
        assert_eq!(tight.assignment[0], 0, "first jobs still fit the cheap backend");
        assert!(
            tight.assignment.iter().any(|&k| k != 0),
            "a 2-lane backend cannot meet 700 s for 10 × 600 s: {:?}",
            tight.assignment
        );
        assert!(tight.projected_makespan_s <= loose.projected_makespan_s);
    }

    #[test]
    fn budget_cap_limits_projected_spend() {
        let fleet = trio();
        let js = jobs(30, 600.0);
        let unlimited = plan(&js, &fleet, PlacementPolicy::BudgetCapped { budget_dollars: 1e9 });
        // with money no object, everything goes to the fastest finish
        assert!(unlimited.assignment.iter().any(|&k| k == 1), "{:?}", unlimited.assignment);

        let broke = plan(&js, &fleet, PlacementPolicy::BudgetCapped { budget_dollars: 0.0 });
        let cheapest = plan(&js, &fleet, PlacementPolicy::CheapestFirst);
        assert_eq!(broke.assignment, cheapest.assignment, "no budget = cheapest damage");

        // a real cap: some premium burst, but spend bounded by the
        // budget plus the unavoidable cheapest-fallback baseline
        let budget = 0.5;
        let capped = plan(&js, &fleet, PlacementPolicy::BudgetCapped { budget_dollars: budget });
        assert!(capped.assignment.iter().any(|&k| k != 0), "{:?}", capped.assignment);
        assert!(
            capped.projected_cost_dollars <= budget + cheapest.projected_cost_dollars + 1e-9,
            "spend {:.4} exceeds budget + cheapest baseline",
            capped.projected_cost_dollars
        );
        assert!(capped.projected_cost_dollars < unlimited.projected_cost_dollars);
        assert!(capped.projected_makespan_s >= unlimited.projected_makespan_s - 1e-9);
    }

    #[test]
    fn pareto_prunes_dominated_and_duplicate_points() {
        let p = |label: &str, cost: f64, mk: f64| FrontierPoint {
            label: label.into(),
            cost_dollars: cost,
            makespan_s: mk,
            jobs_per_backend: vec![],
        };
        let kept = pareto(vec![
            p("a", 1.0, 100.0),
            p("dominated", 2.0, 100.0),
            p("b", 2.0, 50.0),
            p("dup", 2.0, 50.0),
            p("worse-both", 3.0, 60.0),
            p("c", 4.0, 10.0),
        ]);
        let labels: Vec<&str> = kept.iter().map(|p| p.label.as_str()).collect();
        assert_eq!(labels, ["a", "b", "c"]);
        for w in kept.windows(2) {
            assert!(w[0].cost_dollars < w[1].cost_dollars);
            assert!(w[0].makespan_s > w[1].makespan_s);
        }
        assert!(pareto(vec![]).is_empty());
    }

    #[test]
    fn execute_conserves_jobs_and_sums_backend_costs() {
        let mut fleet = trio();
        fleet[0] = BackendSpec {
            name: "hpc".into(),
            env: Env::Hpc,
            kind: BackendKind::Slurm {
                cluster: ClusterSpec::small(2, 4, 16),
                max_concurrent: 8,
            },
            faults: None,
            transfer_streams: 4,
        };
        let js = jobs(24, 120.0);
        let cfg = PlacementConfig::default();
        // 8 HPC slots × 120 s waves: wave 3 misses a 250 s deadline, so
        // the tail must burst off the cluster
        let out = execute(&js, &fleet, PlacementPolicy::DeadlineAware { deadline_s: 250.0 }, &cfg);
        assert_eq!(out.per_backend.iter().map(|u| u.jobs).sum::<usize>(), 24);
        let completed = out.staged.timings.iter().filter(|t| t.completed).count();
        assert_eq!(completed as u64 + out.aborted, 24, "jobs conserved");
        assert_eq!(completed, 24, "clean run completes everything");
        let sum: f64 = out.per_backend.iter().map(|u| u.cost_dollars).sum();
        assert!((sum - out.total_cost_dollars).abs() < 1e-12);
        assert!(out.total_cost_dollars > 0.0);
        assert!(out.makespan_s > 0.0);
        // at least two backends actually used under the tight deadline
        let used = out.per_backend.iter().filter(|u| u.jobs > 0).count();
        assert!(used >= 2, "{:?}", out.plan.assignment);
    }

    #[test]
    fn faulty_placement_is_deterministic_and_bills_waste() {
        let mut fleet = trio();
        for b in &mut fleet {
            b.faults = Some(FaultModel::harsh());
        }
        let cfg = PlacementConfig {
            transfer_faults: Some(FaultModel::harsh()),
            ..Default::default()
        };
        let js = jobs(40, 90.0);
        let run = || execute(&js, &fleet, PlacementPolicy::CheapestFirst, &cfg);
        let a = run();
        let b = run();
        assert_eq!(a.staged.timings, b.staged.timings, "same seed must replay");
        assert_eq!(a.compute_events, b.compute_events);
        assert_eq!(a.transfer_events, b.transfer_events);
        assert_eq!(a.total_cost_dollars, b.total_cost_dollars);
        assert!(!a.compute_events.is_empty(), "harsh rates over 40 jobs must fail attempts");
        // waste is billed: the faulty cost exceeds a clean run's
        let clean_fleet = trio();
        let clean = execute(&js, &clean_fleet, PlacementPolicy::CheapestFirst, &cfg);
        assert!(
            a.total_cost_dollars > clean.total_cost_dollars,
            "faulty {} vs clean {}",
            a.total_cost_dollars,
            clean.total_cost_dollars
        );
    }

    #[test]
    fn frontier_sweep_emits_an_undominated_curve() {
        let fleet = trio();
        let js = jobs(16, 300.0);
        let cfg = PlacementConfig::default();
        let frontier = frontier_sweep(&js, &fleet, &cfg, 3);
        assert!(!frontier.is_empty());
        for (i, p) in frontier.iter().enumerate() {
            assert_eq!(p.jobs_per_backend.iter().sum::<usize>(), 16, "{}", p.label);
            for q in &frontier[i + 1..] {
                let dominates = q.cost_dollars <= p.cost_dollars
                    && q.makespan_s <= p.makespan_s
                    && (q.cost_dollars < p.cost_dollars || q.makespan_s < p.makespan_s);
                let dominated_by = p.cost_dollars <= q.cost_dollars
                    && p.makespan_s <= q.makespan_s
                    && (p.cost_dollars < q.cost_dollars || p.makespan_s < q.makespan_s);
                assert!(!dominates && !dominated_by, "{} vs {}", p.label, q.label);
            }
        }
    }

    use crate::faults::outage::{ComputeOutage, OutageMode};

    #[test]
    fn empty_chaos_schedule_reproduces_execute_exactly() {
        let fleet = trio();
        let js = jobs(12, 180.0);
        let cfg = PlacementConfig::default();
        let plain = execute(&js, &fleet, PlacementPolicy::CheapestFirst, &cfg);
        let chaos = execute_chaos(
            &js,
            &fleet,
            PlacementPolicy::CheapestFirst,
            &cfg,
            &OutageSchedule::empty(),
        );
        assert_eq!(plain.staged.timings, chaos.staged.timings);
        assert_eq!(plain.per_backend, chaos.per_backend);
        assert_eq!(plain.total_cost_dollars, chaos.total_cost_dollars);
        assert_eq!(plain.makespan_s, chaos.makespan_s);
        assert_eq!(plain.transfer, chaos.transfer);
        assert!(plain.outage.is_none(), "chaos-free path reports no stats");
        assert_eq!(chaos.outage, Some(OutageStats::default()));
    }

    #[test]
    fn outage_re_places_orphans_onto_surviving_backends() {
        let fleet = trio(); // hpc = 2 lanes, cheapest: everything plans there
        let js = jobs(8, 300.0);
        let cfg = PlacementConfig::default();
        let mut schedule = OutageSchedule::empty();
        schedule.compute.push(ComputeOutage {
            backend: 0,
            mode: OutageMode::Down,
            start_s: 400.0,
            end_s: 1.0e7,
        });
        let out = execute_chaos(&js, &fleet, PlacementPolicy::CheapestFirst, &cfg, &schedule);
        let stats = out.outage.expect("chaos path reports stats");
        assert!(stats.orphaned > 0, "jobs queued behind 2 lanes must orphan at onset");
        assert_eq!(stats.re_placed, stats.orphaned, "a surviving backend exists for every orphan");
        assert!(stats.killed >= 1, "the running wave dies with the backend");
        assert!(stats.killed_wasted_s > 0.0);
        assert!(out.staged.timings.iter().all(|t| t.completed), "degradation, not loss");
        let moved: usize = out.per_backend.iter().skip(1).map(|u| u.jobs).sum();
        assert_eq!(moved as u64, stats.re_placed, "orphans bill on the backend that ran them");
    }

    #[test]
    fn chaos_runs_replay_given_the_seed() {
        let fleet = trio();
        let js = jobs(20, 150.0);
        let cfg = PlacementConfig::default();
        let schedule = OutageSchedule::synthetic(
            crate::faults::outage::OutageSeverity::Harsh,
            fleet.len(),
            3_000.0,
            cfg.seed,
        );
        let run = || execute_chaos(&js, &fleet, PlacementPolicy::CheapestFirst, &cfg, &schedule);
        let a = run();
        let b = run();
        assert_eq!(a.staged.timings, b.staged.timings);
        assert_eq!(a.outage, b.outage);
        assert_eq!(a.per_backend, b.per_backend);
        assert_eq!(a.total_cost_dollars, b.total_cost_dollars);
    }

    #[test]
    fn shared_topology_assigns_per_backend_stream_caps() {
        let fleet = trio();
        let topo = shared_topology(&fleet);
        for (k, b) in fleet.iter().enumerate() {
            assert_eq!(topo.stream_cap(k as u64), b.transfer_streams);
        }
        // the shared path is the storage-side composite: HPC topology
        assert_eq!(topo.bottleneck_gbps(), Topology::of(Env::Hpc).bottleneck_gbps());
    }
}
