//! Flat structure-of-arrays job storage for the co-simulation hot loops
//! (DESIGN.md §16).
//!
//! The windowed co-simulation driver ([`crate::coordinator::staged`])
//! touches one or two fields of the campaign's working job set per
//! hand-off — `bytes_out` when a compute completion submits its
//! copy-back, `bytes_in` when a parked retry re-stages, `compute_s`
//! when a completion back-computes its start instant. Keeping the
//! working set as a `Vec<StagedJob>` drags the whole 40-byte struct
//! through the cache for every one of those single-field reads and —
//! before [`StagedJob`] became `Copy` — cloned it wholesale at every
//! orphan re-placement. [`JobStore`] splits the campaign into parallel
//! per-field columns so each hand-off reads exactly the column it
//! needs, and jobs are addressed by index everywhere inside the loop;
//! a [`StagedJob`] value is materialized only at the two boundaries
//! that need one (backend submission, final effective-job export).
//!
//! The column values are bit-copies of the input jobs, so a loop
//! reading `store.compute_s(i)` sees exactly the f64 the pre-SoA loop
//! read from `jobs_eff[i].compute_s` — the store cannot perturb the
//! f64-record parity contract (`rust/tests/engine_parity.rs`).

use crate::coordinator::staged::StagedJob;

/// Structure-of-arrays store over a campaign's (possibly re-placed)
/// effective jobs: one flat column per [`StagedJob`] field, indexed by
/// job id.
#[derive(Debug, Clone, Default)]
pub struct JobStore {
    cores: Vec<u32>,
    ram_gb: Vec<u32>,
    compute_s: Vec<f64>,
    bytes_in: Vec<u64>,
    bytes_out: Vec<u64>,
}

impl JobStore {
    /// Split `jobs` into per-field columns (bit-copies, no rescaling).
    pub fn from_jobs(jobs: &[StagedJob]) -> Self {
        let mut store = Self {
            cores: Vec::with_capacity(jobs.len()),
            ram_gb: Vec::with_capacity(jobs.len()),
            compute_s: Vec::with_capacity(jobs.len()),
            bytes_in: Vec::with_capacity(jobs.len()),
            bytes_out: Vec::with_capacity(jobs.len()),
        };
        for j in jobs {
            store.cores.push(j.cores);
            store.ram_gb.push(j.ram_gb);
            store.compute_s.push(j.compute_s);
            store.bytes_in.push(j.bytes_in);
            store.bytes_out.push(j.bytes_out);
        }
        store
    }

    pub fn len(&self) -> usize {
        self.compute_s.len()
    }

    pub fn is_empty(&self) -> bool {
        self.compute_s.is_empty()
    }

    /// Compute wall-clock column, seconds.
    pub fn compute_s(&self, i: usize) -> f64 {
        self.compute_s[i]
    }

    /// Stage-in size column, bytes.
    pub fn bytes_in(&self, i: usize) -> u64 {
        self.bytes_in[i]
    }

    /// Copy-back size column, bytes.
    pub fn bytes_out(&self, i: usize) -> u64 {
        self.bytes_out[i]
    }

    /// Materialize job `i` as a [`StagedJob`] value (backend submission
    /// needs the whole row).
    pub fn job(&self, i: usize) -> StagedJob {
        StagedJob {
            cores: self.cores[i],
            ram_gb: self.ram_gb[i],
            compute_s: self.compute_s[i],
            bytes_in: self.bytes_in[i],
            bytes_out: self.bytes_out[i],
        }
    }

    /// Replace job `i` (orphan re-placement rescales compute to the new
    /// backend's speed).
    pub fn set(&mut self, i: usize, job: StagedJob) {
        self.cores[i] = job.cores;
        self.ram_gb[i] = job.ram_gb;
        self.compute_s[i] = job.compute_s;
        self.bytes_in[i] = job.bytes_in;
        self.bytes_out[i] = job.bytes_out;
    }

    /// Re-assemble the columns into owned jobs (the final effective set
    /// billing folds against).
    pub fn into_jobs(self) -> Vec<StagedJob> {
        (0..self.len()).map(|i| self.job(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(k: u64) -> StagedJob {
        StagedJob {
            cores: 1 + (k % 3) as u32,
            ram_gb: 4,
            compute_s: 60.0 + k as f64,
            bytes_in: 1_000 + k,
            bytes_out: 500 + k,
        }
    }

    #[test]
    fn columns_round_trip_bit_exactly() {
        let jobs: Vec<StagedJob> = (0..17).map(job).collect();
        let store = JobStore::from_jobs(&jobs);
        assert_eq!(store.len(), jobs.len());
        assert!(!store.is_empty());
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(store.job(i), *j);
            assert_eq!(store.compute_s(i).to_bits(), j.compute_s.to_bits());
            assert_eq!(store.bytes_in(i), j.bytes_in);
            assert_eq!(store.bytes_out(i), j.bytes_out);
        }
        assert_eq!(store.into_jobs(), jobs);
    }

    #[test]
    fn set_replaces_one_row_only() {
        let jobs: Vec<StagedJob> = (0..5).map(job).collect();
        let mut store = JobStore::from_jobs(&jobs);
        let replacement = StagedJob {
            compute_s: 9.5,
            ..job(2)
        };
        store.set(2, replacement);
        assert_eq!(store.job(2), replacement);
        for i in [0usize, 1, 3, 4] {
            assert_eq!(store.job(i), jobs[i], "row {i} untouched");
        }
    }

    #[test]
    fn empty_store_is_empty() {
        let store = JobStore::from_jobs(&[]);
        assert!(store.is_empty());
        assert_eq!(store.len(), 0);
        assert!(store.into_jobs().is_empty());
    }
}
