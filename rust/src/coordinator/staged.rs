//! Staged campaign execution (DESIGN.md §9): co-simulate the
//! contention-aware transfer scheduler with a compute backend so a
//! campaign's stage-in, compute, and stage-out phases **overlap** per
//! job — job k computes while job k+1 stages in and job k-1 copies back,
//! exactly the pipeline the paper's Fig. 3 submission loop produces.
//!
//! The previous model billed every job `stage_in + compute + stage_out`
//! as one opaque duration with transfers sampled independently, which
//! both ignored shared-link contention and serialized phases that
//! overlap in reality. Here the two discrete-event simulators advance in
//! lockstep to the globally earliest event (`advance_to` never
//! overshoots), exchanging causality at the two hand-off points:
//!
//! * a **stage-in completion** submits the job to the compute backend
//!   at that instant;
//! * a **compute completion** submits the job's copy-back transfer,
//!   which then contends with still-running stage-ins on the same
//!   shared links.
//!
//! Compute backends implement [`ComputeSim`]: the SLURM cluster
//! simulator ([`SlurmSim`]) for the HPC path and a bounded worker pool
//! ([`LanePool`]) for local bursts.
//!
//! **Event-engine scale (DESIGN.md §10):** the co-simulation loop pulls
//! the next hand-off instant from a merged event heap over its sources,
//! and each source now answers `next_event_time` from its own event
//! index (heap peeks + O(open streams) / O(workers)), so a 10⁶-job
//! campaign runs the loop in near-linear total time. The pre-PR loop —
//! retained in [`crate::sim_legacy`] and proven record-for-record
//! identical by `rust/tests/engine_parity.rs` — polled two O(n)
//! `next_event_time` scans per event.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

use crate::netsim::scheduler::{TransferScheduler, TransferStats};
use crate::slurm::{ArrayHandle, Scheduler, SimJob};
use crate::util::ord::F64Ord;

const EPS: f64 = 1e-9;

/// Host id used for a campaign's staging path (one shared gateway).
const STAGE_HOST: u64 = 0;

/// One job's staged-execution plan.
#[derive(Debug, Clone)]
pub struct StagedJob {
    pub cores: u32,
    pub ram_gb: u32,
    /// Compute wall-clock once started, seconds.
    pub compute_s: f64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

/// Per-job timeline produced by [`run_staged`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StagedTiming {
    /// Queue wait behind the host's stream cap before stage-in flowed.
    pub stage_in_wait_s: f64,
    /// Stage-in wire time under contention (latency + shared-rate bytes).
    pub stage_in_s: f64,
    pub compute_start_s: f64,
    pub compute_end_s: f64,
    pub stage_out_wait_s: f64,
    pub stage_out_s: f64,
    /// Absolute completion time of the verified copy-back.
    pub done_s: f64,
    /// False when the compute backend dropped the job (e.g. oversized
    /// for every node) — its copy-back never ran.
    pub completed: bool,
}

/// Result of one staged campaign execution.
#[derive(Debug, Clone)]
pub struct StagedOutcome {
    pub timings: Vec<StagedTiming>,
    /// Campaign wall-clock: last copy-back (or compute) completion.
    pub makespan_s: f64,
    pub transfer: TransferStats,
}

/// A discrete-event compute backend the staged co-simulation can drive.
pub trait ComputeSim {
    /// Submit job `id`, ready (inputs staged) at `ready_s`.
    fn submit(&mut self, id: u64, ready_s: f64, job: &StagedJob);
    /// Time of the backend's next internal event, `None` when idle.
    fn next_event_time(&self) -> Option<f64>;
    /// Advance to absolute time `t` (never overshooting), returning
    /// `(id, end_s)` for jobs that completed by `t`.
    fn advance_to(&mut self, t: f64) -> Vec<(u64, f64)>;
}

/// The SLURM cluster simulator as a staged-campaign compute backend.
pub struct SlurmSim {
    sched: Scheduler,
    user: String,
    array: Option<ArrayHandle>,
    cursor: usize,
}

impl SlurmSim {
    pub fn new(sched: Scheduler, user: &str, array: Option<ArrayHandle>) -> Self {
        Self {
            sched,
            user: user.to_string(),
            array,
            cursor: 0,
        }
    }

    pub fn scheduler(&self) -> &Scheduler {
        &self.sched
    }
}

impl ComputeSim for SlurmSim {
    fn submit(&mut self, id: u64, ready_s: f64, job: &StagedJob) {
        self.sched.submit(SimJob {
            id,
            user: self.user.clone(),
            cores: job.cores,
            ram_gb: job.ram_gb,
            duration_s: job.compute_s,
            submit_s: ready_s.max(self.sched.clock()),
            array: self.array,
        });
    }

    fn next_event_time(&self) -> Option<f64> {
        self.sched.next_event_time()
    }

    fn advance_to(&mut self, t: f64) -> Vec<(u64, f64)> {
        self.sched.advance_to(t);
        let recs = self.sched.records();
        let done = recs[self.cursor..]
            .iter()
            .map(|r| (r.job.id, r.end_s))
            .collect();
        self.cursor = recs.len();
        done
    }
}

/// A bounded pool of identical worker lanes (the local-burst backend):
/// jobs start FIFO by readiness as lanes free up — the discrete-event
/// equivalent of `util::pool`'s bounded in-flight backpressure.
///
/// Scale note (DESIGN.md §10): ready jobs wait in an ordered map keyed
/// by (ready, id) and future readies in a binary heap, so starting a
/// job is O(log n) instead of the pre-PR full-queue scan; completions
/// still replay the original lane/collection order exactly
/// (`rust/tests/engine_parity.rs`).
pub struct LanePool {
    /// Each lane's busy-until time.
    lanes: Vec<f64>,
    /// Ready-to-run jobs, FIFO by (ready_s, id) → duration.
    due: BTreeMap<(F64Ord, u64), f64>,
    /// Not-yet-ready jobs, min-heap by (ready_s, id), carrying duration.
    future: BinaryHeap<Reverse<(F64Ord, u64, F64Ord)>>,
    /// (id, end_s) currently running.
    running: Vec<(u64, f64)>,
    clock: f64,
}

impl LanePool {
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1, "lane pool needs at least one worker");
        Self {
            lanes: vec![0.0; workers],
            due: BTreeMap::new(),
            future: BinaryHeap::new(),
            running: Vec::new(),
            clock: 0.0,
        }
    }

    /// Start queued-and-ready jobs on free lanes, FIFO by (ready, id).
    fn start_ready(&mut self) {
        while let Some(&Reverse((ready, id, dur))) = self.future.peek() {
            if ready.0 > self.clock + EPS {
                break; // min-heap: everything after is future too
            }
            self.future.pop();
            self.due.insert((ready, id), dur.0);
        }
        loop {
            if self.due.is_empty() {
                return;
            }
            let Some(lane) = self.lanes.iter().position(|&f| f <= self.clock + EPS) else {
                return;
            };
            let ((_, id), dur) = self.due.pop_first().expect("non-empty due map");
            self.lanes[lane] = self.clock + dur;
            self.running.push((id, self.clock + dur));
        }
    }
}

impl ComputeSim for LanePool {
    fn submit(&mut self, id: u64, ready_s: f64, job: &StagedJob) {
        let ready = ready_s.max(self.clock);
        if ready <= self.clock + EPS {
            self.due.insert((F64Ord(ready), id), job.compute_s);
            self.start_ready();
        } else {
            self.future.push(Reverse((F64Ord(ready), id, F64Ord(job.compute_s))));
        }
    }

    fn next_event_time(&self) -> Option<f64> {
        let mut t = f64::INFINITY;
        for &(_, end) in &self.running {
            t = t.min(end);
        }
        if let Some(&Reverse((ready, ..))) = self.future.peek() {
            t = t.min(ready.0);
        }
        t.is_finite().then_some(t)
    }

    fn advance_to(&mut self, t: f64) -> Vec<(u64, f64)> {
        assert!(t + EPS >= self.clock, "cannot advance backwards");
        let mut done = Vec::new();
        loop {
            self.start_ready();
            let target = match self.next_event_time() {
                Some(x) if x <= t => x,
                _ => t,
            };
            self.clock = self.clock.max(target);
            let mut i = 0;
            while i < self.running.len() {
                if self.running[i].1 <= self.clock + EPS {
                    done.push(self.running.swap_remove(i));
                } else {
                    i += 1;
                }
            }
            if target + EPS >= t {
                self.start_ready();
                return done;
            }
        }
    }
}

const fn stage_in_id(i: usize) -> u64 {
    (i as u64) * 2
}

const fn stage_out_id(i: usize) -> u64 {
    (i as u64) * 2 + 1
}

/// Merged event heap over the co-simulation's sources: each iteration
/// re-arms every source with its current `next_event_time` and pops the
/// globally earliest one.
///
/// Why re-arm instead of caching entries across iterations: the
/// transfer side is a fluid model — every hand-off re-splits fair-share
/// rates, and even an event-free `advance_to` moves `bytes_left`, so a
/// drain time computed at an older clock differs in the last f64 bits
/// from one computed now. Cached heap entries would drift from the
/// pre-PR polling loop and break record-for-record parity
/// (`rust/tests/engine_parity.rs`). Re-arming is O(sources · log
/// sources) per event against sources whose `next_event_time` is now a
/// heap peek — the O(n) per-event scans this heap used to sit on top
/// of are gone (DESIGN.md §10).
struct MergedEvents {
    heap: BinaryHeap<Reverse<F64Ord>>,
}

impl MergedEvents {
    fn new() -> Self {
        Self {
            heap: BinaryHeap::with_capacity(4),
        }
    }

    fn arm(&mut self, next: Option<f64>) {
        if let Some(t) = next {
            self.heap.push(Reverse(F64Ord(t)));
        }
    }

    /// Earliest armed event time; clears the heap for the next re-arm.
    fn pop_earliest(&mut self) -> Option<f64> {
        let Reverse(t) = self.heap.pop()?;
        self.heap.clear();
        Some(t.0)
    }
}

/// Run a campaign's jobs through the staged pipeline: all stage-ins are
/// submitted to the (shared, contended) transfer scheduler at t=0, each
/// job enters the compute backend the moment its inputs land, and each
/// copy-back is submitted the moment compute finishes — so the three
/// phases overlap across jobs and every transfer sees the contention
/// actually present at that simulated instant.
pub fn run_staged(
    jobs: &[StagedJob],
    compute: &mut dyn ComputeSim,
    transfers: &mut TransferScheduler,
) -> StagedOutcome {
    let mut timings = vec![StagedTiming::default(); jobs.len()];
    for (i, j) in jobs.iter().enumerate() {
        transfers.submit_at(stage_in_id(i), STAGE_HOST, j.bytes_in, 0.0);
    }
    let mut events = MergedEvents::new();
    let mut seen = 0usize;
    loop {
        events.arm(transfers.next_event_time());
        events.arm(compute.next_event_time());
        let Some(t) = events.pop_earliest() else { break };
        // both engines advance to the merged-earliest instant — the
        // hand-offs below assume a shared clock
        transfers.advance_to(t);
        // borrow, don't clone: this loop only reads the new completions
        // (it mutates `compute` and `timings`, never `transfers`)
        let records = transfers.records();
        let new_from = seen;
        seen = records.len();
        for r in &records[new_from..] {
            let i = (r.id / 2) as usize;
            if r.id % 2 == 0 {
                timings[i].stage_in_wait_s = r.queue_wait_s();
                timings[i].stage_in_s = r.transfer_s();
                compute.submit(i as u64, r.end_s, &jobs[i]);
            } else {
                timings[i].stage_out_wait_s = r.queue_wait_s();
                timings[i].stage_out_s = r.transfer_s();
                timings[i].done_s = r.end_s;
                timings[i].completed = true;
            }
        }
        for (id, end_s) in compute.advance_to(t) {
            let i = id as usize;
            timings[i].compute_end_s = end_s;
            timings[i].compute_start_s = end_s - jobs[i].compute_s;
            transfers.submit_at(stage_out_id(i), STAGE_HOST, jobs[i].bytes_out, end_s);
        }
    }
    let makespan_s = timings
        .iter()
        .map(|x| x.compute_end_s)
        .fold(transfers.stats().makespan_s, f64::max);
    StagedOutcome {
        makespan_s,
        transfer: transfers.stats(),
        timings,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::scheduler::TransferScheduler;
    use crate::netsim::Env;
    use crate::slurm::ClusterSpec;

    fn jobs(n: usize, compute_s: f64) -> Vec<StagedJob> {
        (0..n)
            .map(|_| StagedJob {
                cores: 1,
                ram_gb: 1,
                compute_s,
                bytes_in: 100_000_000,
                bytes_out: 50_000_000,
            })
            .collect()
    }

    #[test]
    fn lane_pool_caps_concurrency() {
        let js = jobs(4, 100.0);
        let mut lanes = LanePool::new(2);
        let mut transfers = TransferScheduler::for_env(Env::Local, 4, 1);
        let out = run_staged(&js, &mut lanes, &mut transfers);
        assert!(out.timings.iter().all(|t| t.completed));
        // 4 × 100 s of compute through 2 lanes needs at least two waves
        let end = out.timings.iter().map(|t| t.compute_end_s).fold(0.0, f64::max);
        assert!(end >= 200.0, "end={end}");
    }

    #[test]
    fn stage_in_compute_stage_out_overlap() {
        let js = jobs(6, 300.0);
        let mut lanes = LanePool::new(6);
        let mut transfers = TransferScheduler::for_env(Env::Local, 2, 7);
        let out = run_staged(&js, &mut lanes, &mut transfers);
        for t in &out.timings {
            assert!(t.completed);
            // compute starts only after the staged inputs land
            assert!(t.compute_start_s + 1e-6 >= t.stage_in_wait_s + t.stage_in_s);
            assert!(t.done_s + 1e-9 >= t.compute_end_s);
            assert!(t.stage_in_s > 0.0 && t.stage_out_s > 0.0);
        }
        // overlap must beat running every phase back to back
        let serial: f64 = out
            .timings
            .iter()
            .map(|t| t.stage_in_s + (t.compute_end_s - t.compute_start_s) + t.stage_out_s)
            .sum();
        assert!(
            out.makespan_s < serial,
            "phases must overlap: makespan {} vs serialized {serial}",
            out.makespan_s
        );
    }

    #[test]
    fn slurm_backend_respects_cluster_capacity() {
        let js = jobs(3, 100.0);
        let sched = Scheduler::new(ClusterSpec::small(1, 1, 4)); // one core
        let mut sim = SlurmSim::new(sched, "medflow", None);
        let mut transfers = TransferScheduler::for_env(Env::Hpc, 3, 3);
        let out = run_staged(&js, &mut sim, &mut transfers);
        assert!(out.timings.iter().all(|t| t.completed));
        // 3 × 100 s of compute through one core can never beat 300 s
        let end = out.timings.iter().map(|t| t.compute_end_s).fold(0.0, f64::max);
        assert!(end >= 300.0 - 1e-6, "end={end}");
        assert!(out.makespan_s > end - 1e-9, "copy-back extends the makespan");
    }

    #[test]
    fn copy_back_contends_with_late_stage_ins() {
        // a stream cap of 1 forces stage-ins to trickle; early jobs'
        // copy-backs are submitted while later stage-ins still queue, and
        // everything funnels through the same shared path FIFO
        let js = jobs(3, 1.0);
        let mut lanes = LanePool::new(3);
        let mut transfers = TransferScheduler::for_env(Env::Local, 1, 11);
        let out = run_staged(&js, &mut lanes, &mut transfers);
        assert!(out.timings.iter().all(|t| t.completed));
        let waits: f64 = out
            .timings
            .iter()
            .map(|t| t.stage_in_wait_s + t.stage_out_wait_s)
            .sum();
        assert!(waits > 0.0, "cap 1 must queue some transfer");
        assert_eq!(out.transfer.transfers, 6);
        assert_eq!(out.transfer.peak_streams, 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let js = jobs(5, 50.0);
        let run = || {
            let mut lanes = LanePool::new(2);
            let mut transfers = TransferScheduler::for_env(Env::Cloud, 4, 23);
            run_staged(&js, &mut lanes, &mut transfers).timings
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn empty_campaign_is_a_noop() {
        let mut lanes = LanePool::new(2);
        let mut transfers = TransferScheduler::for_env(Env::Hpc, 4, 1);
        let out = run_staged(&[], &mut lanes, &mut transfers);
        assert!(out.timings.is_empty());
        assert_eq!(out.makespan_s, 0.0);
        assert_eq!(out.transfer.transfers, 0);
    }

    #[test]
    fn wide_campaign_stays_near_linear() {
        // 5k jobs through the co-simulation in a debug-build test: the
        // pre-PR polling loop (O(n) next_event_time per event) made this
        // minutes; the merged heap + indexed engines keep it seconds.
        let js: Vec<StagedJob> = (0..5_000)
            .map(|i| StagedJob {
                cores: 1,
                ram_gb: 1,
                compute_s: 30.0 + (i % 7) as f64 * 10.0,
                bytes_in: 5_000_000,
                bytes_out: 1_000_000,
            })
            .collect();
        let mut lanes = LanePool::new(64);
        let mut transfers = TransferScheduler::for_env(Env::Local, 32, 17);
        let out = run_staged(&js, &mut lanes, &mut transfers);
        assert!(out.timings.iter().all(|t| t.completed));
        assert_eq!(out.transfer.transfers, 10_000);
    }
}
