//! Staged campaign execution (DESIGN.md §9): co-simulate the
//! contention-aware transfer scheduler with a compute backend so a
//! campaign's stage-in, compute, and stage-out phases **overlap** per
//! job — job k computes while job k+1 stages in and job k-1 copies back,
//! exactly the pipeline the paper's Fig. 3 submission loop produces.
//!
//! The previous model billed every job `stage_in + compute + stage_out`
//! as one opaque duration with transfers sampled independently, which
//! both ignored shared-link contention and serialized phases that
//! overlap in reality. Here the two discrete-event simulators advance in
//! lockstep to the globally earliest event (`advance_to` never
//! overshoots), exchanging causality at the two hand-off points:
//!
//! * a **stage-in completion** submits the job to the compute backend
//!   at that instant;
//! * a **compute completion** submits the job's copy-back transfer,
//!   which then contends with still-running stage-ins on the same
//!   shared links.
//!
//! Compute backends implement [`ComputeSim`]: the SLURM cluster
//! simulator ([`SlurmSim`]) for the HPC path and a bounded worker pool
//! ([`LanePool`]) for local bursts.
//!
//! **Event-engine scale (DESIGN.md §10):** the co-simulation loop pulls
//! the next hand-off instant from a merged event heap over its sources,
//! and each source now answers `next_event_time` from its own event
//! index (heap peeks + O(open streams) / O(workers)), so a 10⁶-job
//! campaign runs the loop in near-linear total time. The pre-PR loop —
//! retained in [`crate::sim_legacy`] and proven record-for-record
//! identical by `rust/tests/engine_parity.rs` — polled two O(n)
//! `next_event_time` scans per event.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap};

use crate::coordinator::soa::JobStore;
use crate::coordinator::spec::RunSpec;
use crate::coordinator::sync::{with_driver, BackendStep, WindowDriver};
use crate::faults::outage::{OutageMode, OutageWindow};
use crate::faults::{FailureMode, FaultAction, FaultEvent, Injection};
use crate::netsim::scheduler::{TransferScheduler, TransferStats};
use crate::slurm::{ArrayHandle, Scheduler, SimJob};
use crate::util::ord::F64Ord;
use crate::util::rng::Rng;

const EPS: f64 = 1e-9;

/// One job's staged-execution plan. `Copy`: five plain-old-data fields
/// that the SoA store ([`crate::coordinator::soa::JobStore`]) and the
/// window drivers pass by bit-copy instead of heap clones.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StagedJob {
    pub cores: u32,
    pub ram_gb: u32,
    /// Compute wall-clock once started, seconds.
    pub compute_s: f64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

/// Synthetic fault-sweep campaign: 1-core jobs with 1–10 minute compute
/// and tens of MB staged in/out. One definition shared by the `medflow
/// faults` CLI, `benches/fault_resilience.rs`, and
/// `rust/tests/fault_cosim.rs`, so their outputs stay cross-comparable
/// for the same (n, seed).
pub fn synthetic_fault_campaign(n: usize, seed: u64) -> Vec<StagedJob> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| StagedJob {
            cores: 1,
            ram_gb: 4,
            compute_s: 60.0 + rng.next_f64() * 540.0,
            bytes_in: 10_000_000 + rng.below(40_000_000),
            bytes_out: 2_000_000 + rng.below(8_000_000),
        })
        .collect()
}

/// Per-job timeline produced by [`run_staged`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StagedTiming {
    /// Queue wait behind the host's stream cap before stage-in flowed.
    pub stage_in_wait_s: f64,
    /// Stage-in wire time under contention (latency + shared-rate bytes).
    pub stage_in_s: f64,
    pub compute_start_s: f64,
    pub compute_end_s: f64,
    pub stage_out_wait_s: f64,
    pub stage_out_s: f64,
    /// Absolute completion time of the verified copy-back.
    pub done_s: f64,
    /// False when the compute backend dropped the job (e.g. oversized
    /// for every node) — its copy-back never ran.
    pub completed: bool,
}

/// Result of one staged campaign execution.
#[derive(Debug, Clone)]
pub struct StagedOutcome {
    pub timings: Vec<StagedTiming>,
    /// Campaign wall-clock: last copy-back (or compute) completion.
    pub makespan_s: f64,
    pub transfer: TransferStats,
}

/// A discrete-event compute backend the staged co-simulation can drive.
///
/// `Send` is a supertrait so the conservative window-sync layer
/// ([`crate::coordinator::sync`]) can hand a backend to its worker
/// thread for the duration of a run; every engine here is plain owned
/// state, so the bound costs nothing.
pub trait ComputeSim: Send {
    /// Submit job `id`, ready (inputs staged) at `ready_s`.
    fn submit(&mut self, id: u64, ready_s: f64, job: &StagedJob);
    /// Time of the backend's next internal event, `None` when idle.
    fn next_event_time(&self) -> Option<f64>;
    /// Advance to absolute time `t` (never overshooting), returning
    /// `(id, end_s)` for jobs that completed by `t`.
    fn advance_to(&mut self, t: f64) -> Vec<(u64, f64)>;
    /// Drain (job id, fail time) pairs whose last attempt timed out with
    /// in-engine fault injection parked ([`Injection::park_timeouts`]):
    /// the timeout wiped node-local scratch, so [`run_staged`] must
    /// re-stage the job's inputs and resubmit it when they land.
    /// Backends without injection return nothing.
    fn take_restage(&mut self) -> Vec<(u64, f64)> {
        Vec::new()
    }
    /// Drain (job id, onset time) pairs released back to the planner at
    /// an outage onset (DESIGN.md §15): the backend orphaned its queue,
    /// so [`run_multi_chaos`] must re-place each job — a fresh stage-in
    /// to the chosen backend, then resubmission when it lands. Backends
    /// without an outage schedule return nothing.
    fn take_orphans(&mut self) -> Vec<(u64, f64)> {
        Vec::new()
    }
    /// Cumulative count of jobs dropped after exhausting retries.
    /// Admission control (tenancy) frees queue slots off the deltas
    /// between windows; backends without injection never abort.
    fn aborted_count(&self) -> usize {
        0
    }
}

/// The SLURM cluster simulator as a staged-campaign compute backend.
pub struct SlurmSim {
    sched: Scheduler,
    user: String,
    array: Option<ArrayHandle>,
    cursor: usize,
}

impl SlurmSim {
    pub fn new(sched: Scheduler, user: &str, array: Option<ArrayHandle>) -> Self {
        Self {
            sched,
            user: user.to_string(),
            array,
            cursor: 0,
        }
    }

    pub fn scheduler(&self) -> &Scheduler {
        &self.sched
    }

    /// Mutable scheduler access for pre-run configuration (e.g.
    /// [`Scheduler::set_outages`]); the co-simulation itself drives the
    /// engine only through [`ComputeSim`].
    pub fn scheduler_mut(&mut self) -> &mut Scheduler {
        &mut self.sched
    }
}

impl ComputeSim for SlurmSim {
    fn submit(&mut self, id: u64, ready_s: f64, job: &StagedJob) {
        self.sched.submit(SimJob {
            id,
            user: self.user.clone(),
            cores: job.cores,
            ram_gb: job.ram_gb,
            duration_s: job.compute_s,
            submit_s: ready_s.max(self.sched.clock()),
            array: self.array,
        });
    }

    fn next_event_time(&self) -> Option<f64> {
        self.sched.next_event_time()
    }

    fn advance_to(&mut self, t: f64) -> Vec<(u64, f64)> {
        self.sched.advance_to(t);
        let recs = self.sched.records();
        let done = recs[self.cursor..]
            .iter()
            .map(|r| (r.job.id, r.end_s))
            .collect();
        self.cursor = recs.len();
        done
    }

    fn take_restage(&mut self) -> Vec<(u64, f64)> {
        self.sched.take_parked()
    }

    fn take_orphans(&mut self) -> Vec<(u64, f64)> {
        self.sched.take_orphans()
    }

    fn aborted_count(&self) -> usize {
        self.sched.aborted_ids().len()
    }
}

/// A bounded pool of identical worker lanes (the local-burst backend):
/// jobs start FIFO by readiness as lanes free up — the discrete-event
/// equivalent of `util::pool`'s bounded in-flight backpressure.
///
/// Scale note (DESIGN.md §10): ready jobs wait in an ordered map keyed
/// by (ready, id) and future readies in a binary heap, so starting a
/// job is O(log n) instead of the pre-PR full-queue scan; completions
/// still replay the original lane/collection order exactly
/// (`rust/tests/engine_parity.rs`).
///
/// In-engine fault injection (DESIGN.md §11) mirrors
/// [`crate::slurm::Scheduler::set_faults`]: a failing attempt holds its
/// lane for `wasted_fraction()` of the duration, then requeues with
/// backoff, parks for re-staging (timeouts), or aborts.
pub struct LanePool {
    /// Each lane's busy-until time.
    lanes: Vec<f64>,
    /// Ready-to-run jobs, FIFO by (ready_s, id) → duration.
    due: BTreeMap<(F64Ord, u64), f64>,
    /// Not-yet-ready jobs, min-heap by (ready_s, id), carrying duration.
    future: BinaryHeap<Reverse<(F64Ord, u64, F64Ord)>>,
    /// Attempts currently occupying a lane.
    running: Vec<LaneRun>,
    clock: f64,
    /// In-engine failure injection; `None` = the fault-free engine.
    faults: Option<Injection>,
    /// Job id → retry count so far (only jobs with ≥ 1 failed attempt).
    attempts: HashMap<u64, u32>,
    fault_events: Vec<FaultEvent>,
    /// (job id, fail time) awaiting external re-stage + resubmit.
    parked: Vec<(u64, f64)>,
    aborted: Vec<u64>,
    /// Backend outage windows (DESIGN.md §15); empty = immortal pool.
    outages: Vec<OutageWindow>,
    /// Onset-processed flag per window, aligned with `outages`.
    outage_fired: Vec<bool>,
    /// Requeue delay for attempts killed at a `Down` onset.
    outage_backoff_s: f64,
    /// Queued jobs released to the planner at onsets: (job id, onset time).
    orphans: Vec<(u64, f64)>,
    outage_killed: u64,
    outage_wasted_s: f64,
}

/// One attempt occupying a lane.
struct LaneRun {
    id: u64,
    /// When the attempt releases the lane (failure instant if failing).
    end_s: f64,
    /// Nominal full duration (requeues need it back).
    duration_s: f64,
    attempt: u32,
    fail: Option<FailureMode>,
}

impl LanePool {
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1, "lane pool needs at least one worker");
        Self {
            lanes: vec![0.0; workers],
            due: BTreeMap::new(),
            future: BinaryHeap::new(),
            running: Vec::new(),
            clock: 0.0,
            faults: None,
            attempts: HashMap::new(),
            fault_events: Vec::new(),
            parked: Vec::new(),
            aborted: Vec::new(),
            outages: Vec::new(),
            outage_fired: Vec::new(),
            outage_backoff_s: 0.0,
            orphans: Vec::new(),
            outage_killed: 0,
            outage_wasted_s: 0.0,
        }
    }

    /// Install the pool's outage windows (before submitting work),
    /// mirroring [`crate::slurm::Scheduler::set_outages`]: no job starts
    /// inside a window; each onset orphans the queue back to the planner
    /// and — under [`OutageMode::Down`] — kills every running attempt
    /// (progress wasted), requeueing it after `kill_backoff_s`. An empty
    /// schedule is bit-identical to never calling this.
    pub fn set_outages(&mut self, windows: Vec<OutageWindow>, kill_backoff_s: f64) {
        for w in &windows {
            assert!(
                w.start_s.is_finite() && w.end_s.is_finite() && w.start_s >= 0.0,
                "outage window bounds must be finite and ≥ 0"
            );
            assert!(w.end_s > w.start_s, "outage window end must exceed start");
        }
        assert!(
            kill_backoff_s.is_finite() && kill_backoff_s >= 0.0,
            "kill backoff must be finite and ≥ 0"
        );
        assert!(
            self.running.is_empty() && self.due.is_empty() && self.future.is_empty(),
            "set_outages must precede all submissions"
        );
        self.outage_fired = vec![false; windows.len()];
        self.outages = windows;
        self.outage_backoff_s = kill_backoff_s;
    }

    /// Running attempts killed at `Down` onsets so far.
    pub fn outage_killed(&self) -> u64 {
        self.outage_killed
    }

    /// Lane seconds wasted by outage-killed attempts so far.
    pub fn outage_wasted_s(&self) -> f64 {
        self.outage_wasted_s
    }

    /// True if the clock sits inside any outage window (no job starts).
    fn in_outage(&self) -> bool {
        self.outages
            .iter()
            .any(|w| self.clock >= w.start_s && self.clock < w.end_s)
    }

    /// Fire every outage onset the clock has reached, once per window:
    /// orphan the due queue back to the planner; under `Down` also kill
    /// the running attempts — waste recorded, lanes freed, retries
    /// requeued after the kill backoff. A no-op without a schedule.
    fn process_outage_onsets(&mut self) {
        for k in 0..self.outages.len() {
            if self.outage_fired[k] || self.clock < self.outages[k].start_s {
                continue;
            }
            self.outage_fired[k] = true;
            let w = self.outages[k];
            for ((_, id), _) in std::mem::take(&mut self.due) {
                self.orphans.push((id, self.clock));
            }
            if w.mode == OutageMode::Down {
                for run in std::mem::take(&mut self.running) {
                    let alloc = match run.fail {
                        Some(mode) => run.duration_s * mode.wasted_fraction(),
                        None => run.duration_s,
                    };
                    self.outage_killed += 1;
                    self.outage_wasted_s += (self.clock - (run.end_s - alloc)).max(0.0);
                    self.enqueue(run.id, self.clock + self.outage_backoff_s, run.duration_s);
                }
                // `Down` kills everything at once, so resetting every
                // busy lane to the kill instant is exact
                for lane in &mut self.lanes {
                    if *lane > self.clock {
                        *lane = self.clock;
                    }
                }
            }
        }
    }

    /// Enable in-engine failure injection (before submitting work).
    pub fn set_faults(&mut self, inj: Injection) {
        if let Err(e) = inj.model.validate() {
            panic!("LanePool::set_faults: {e}");
        }
        assert!(
            self.running.is_empty() && self.due.is_empty() && self.future.is_empty(),
            "set_faults must precede all submissions"
        );
        self.faults = Some(inj);
    }

    /// Failed-attempt events recorded so far (empty without injection).
    pub fn fault_events(&self) -> &[FaultEvent] {
        &self.fault_events
    }

    /// Jobs dropped after exhausting their retries.
    pub fn aborted_ids(&self) -> &[u64] {
        &self.aborted
    }

    /// Lane seconds consumed by failed attempts so far.
    pub fn wasted_alloc_s(&self) -> f64 {
        self.fault_events.iter().map(|e| e.wasted_s).sum()
    }

    /// Queue a job attempt, due (ready ≤ clock) or future.
    fn enqueue(&mut self, id: u64, ready: f64, duration_s: f64) {
        if ready <= self.clock + EPS {
            self.due.insert((F64Ord(ready), id), duration_s);
        } else {
            self.future.push(Reverse((F64Ord(ready), id, F64Ord(duration_s))));
        }
    }

    /// Start queued-and-ready jobs on free lanes, FIFO by (ready, id).
    fn start_ready(&mut self) {
        self.process_outage_onsets();
        while let Some(&Reverse((ready, id, dur))) = self.future.peek() {
            if ready.0 > self.clock + EPS {
                break; // min-heap: everything after is future too
            }
            self.future.pop();
            self.due.insert((ready, id), dur.0);
        }
        if self.in_outage() {
            return; // nothing starts inside a window
        }
        loop {
            if self.due.is_empty() {
                return;
            }
            let Some(lane) = self.lanes.iter().position(|&f| f <= self.clock + EPS) else {
                return;
            };
            let ((_, id), dur) = self.due.pop_first().expect("non-empty due map");
            let attempt = self.attempts.get(&id).copied().unwrap_or(0);
            let fail = match &self.faults {
                Some(inj) => inj.sample(id, attempt),
                None => None,
            };
            // fault-free, alloc IS dur: bit-identical to the pre-fault pool
            let alloc = match fail {
                Some(mode) => dur * mode.wasted_fraction(),
                None => dur,
            };
            self.lanes[lane] = self.clock + alloc;
            self.running.push(LaneRun {
                id,
                end_s: self.clock + alloc,
                duration_s: dur,
                attempt,
                fail,
            });
        }
    }

    /// A sampled-to-fail attempt released its lane: requeue / park /
    /// abort, mirroring [`crate::slurm::Scheduler`]'s policy.
    fn fail_attempt(&mut self, run: LaneRun, mode: FailureMode) {
        let inj = self.faults.expect("failing attempt implies an injection config");
        let wasted_s = run.duration_s * mode.wasted_fraction();
        let action = inj.disposition(run.attempt, mode);
        match action {
            FaultAction::Aborted => {
                self.attempts.remove(&run.id);
                self.aborted.push(run.id);
            }
            FaultAction::Parked => {
                self.attempts.insert(run.id, run.attempt + 1);
                self.parked.push((run.id, run.end_s));
            }
            FaultAction::Requeued => {
                self.attempts.insert(run.id, run.attempt + 1);
                let ready = (run.end_s + inj.backoff_s(run.attempt)).max(self.clock);
                self.enqueue(run.id, ready, run.duration_s);
            }
        }
        self.fault_events.push(FaultEvent {
            id: run.id,
            attempt: run.attempt,
            mode,
            fail_s: run.end_s,
            wasted_s,
            action,
        });
    }
}

impl ComputeSim for LanePool {
    fn submit(&mut self, id: u64, ready_s: f64, job: &StagedJob) {
        let ready = ready_s.max(self.clock);
        if ready <= self.clock + EPS {
            self.due.insert((F64Ord(ready), id), job.compute_s);
            self.start_ready();
        } else {
            self.future.push(Reverse((F64Ord(ready), id, F64Ord(job.compute_s))));
        }
    }

    fn next_event_time(&self) -> Option<f64> {
        let mut t = f64::INFINITY;
        for run in &self.running {
            t = t.min(run.end_s);
        }
        if let Some(&Reverse((ready, ..))) = self.future.peek() {
            t = t.min(ready.0);
        }
        // outage boundaries are events: onsets must fire exactly on time
        // (they orphan the queue) and blocked starts resume at each
        // window's end
        for (k, w) in self.outages.iter().enumerate() {
            if !self.outage_fired[k] && w.start_s > self.clock + EPS {
                t = t.min(w.start_s);
            }
            if w.start_s <= self.clock && w.end_s > self.clock && !self.due.is_empty() {
                t = t.min(w.end_s);
            }
        }
        t.is_finite().then_some(t)
    }

    fn advance_to(&mut self, t: f64) -> Vec<(u64, f64)> {
        assert!(t + EPS >= self.clock, "cannot advance backwards");
        let mut done = Vec::new();
        loop {
            self.start_ready();
            let target = match self.next_event_time() {
                Some(x) if x <= t => x,
                _ => t,
            };
            self.clock = self.clock.max(target);
            let mut i = 0;
            while i < self.running.len() {
                if self.running[i].end_s <= self.clock + EPS {
                    let run = self.running.swap_remove(i);
                    match run.fail {
                        None => done.push((run.id, run.end_s)),
                        Some(mode) => self.fail_attempt(run, mode),
                    }
                } else {
                    i += 1;
                }
            }
            if target + EPS >= t {
                self.start_ready();
                return done;
            }
        }
    }

    fn take_restage(&mut self) -> Vec<(u64, f64)> {
        std::mem::take(&mut self.parked)
    }

    fn take_orphans(&mut self) -> Vec<(u64, f64)> {
        std::mem::take(&mut self.orphans)
    }

    fn aborted_count(&self) -> usize {
        self.aborted.len()
    }
}

pub(crate) const fn stage_in_id(i: usize) -> u64 {
    (i as u64) * 2
}

pub(crate) const fn stage_out_id(i: usize) -> u64 {
    (i as u64) * 2 + 1
}

/// Merged event heap over the co-simulation's sources: each iteration
/// re-arms every source with its current `next_event_time` and pops the
/// globally earliest one.
///
/// Why re-arm instead of caching entries across iterations: the
/// transfer side is a fluid model — every hand-off re-splits fair-share
/// rates, and even an event-free `advance_to` moves `bytes_left`, so a
/// drain time computed at an older clock differs in the last f64 bits
/// from one computed now. Cached heap entries would drift from the
/// pre-PR polling loop and break record-for-record parity
/// (`rust/tests/engine_parity.rs`). Re-arming is O(sources · log
/// sources) per event against sources whose `next_event_time` is now a
/// heap peek — the O(n) per-event scans this heap used to sit on top
/// of are gone (DESIGN.md §10).
pub(crate) struct MergedEvents {
    heap: BinaryHeap<Reverse<F64Ord>>,
}

impl MergedEvents {
    pub(crate) fn new() -> Self {
        Self {
            heap: BinaryHeap::with_capacity(4),
        }
    }

    pub(crate) fn arm(&mut self, next: Option<f64>) {
        if let Some(t) = next {
            self.heap.push(Reverse(F64Ord(t)));
        }
    }

    /// Earliest armed event time; clears the heap for the next re-arm.
    pub(crate) fn pop_earliest(&mut self) -> Option<f64> {
        let Reverse(t) = self.heap.pop()?;
        self.heap.clear();
        Some(t.0)
    }
}

/// Run a campaign's jobs through the staged pipeline: all stage-ins are
/// submitted to the (shared, contended) transfer scheduler at t=0, each
/// job enters the compute backend the moment its inputs land, and each
/// copy-back is submitted the moment compute finishes — so the three
/// phases overlap across jobs and every transfer sees the contention
/// actually present at that simulated instant.
///
/// With in-engine fault injection (DESIGN.md §11) both engines retry
/// internally; the one cross-engine hand-off is the **timeout →
/// re-stage** path: a timed-out compute attempt parks
/// ([`Injection::park_timeouts`]), this loop submits a fresh stage-in
/// (ids above the `2·jobs` range), and the job re-enters the compute
/// backend only when the re-staged inputs land — re-contending for the
/// shared link and the cluster both. Fault-free, the loop and every id
/// it submits are identical to the pre-injection engine
/// (`rust/tests/engine_parity.rs`).
pub fn run_staged(
    jobs: &[StagedJob],
    compute: &mut dyn ComputeSim,
    transfers: &mut TransferScheduler,
) -> StagedOutcome {
    let assignment = vec![0usize; jobs.len()];
    run_multi_impl(jobs, &assignment, &mut [compute], transfers, None, 1).0
}

/// Multi-backend staged co-simulation (DESIGN.md §12): one campaign
/// split across several simultaneously simulated compute backends —
/// `assignment[i]` names the backend job `i` runs on — all sharing one
/// [`TransferScheduler`]. Each backend is a distinct *host* on the
/// shared staging path (host id = backend index), so every backend's
/// stage-ins and copy-backs contend for the same bottleneck link while
/// per-host stream caps model each backend's own admission width.
///
/// This is [`run_staged`] generalized: with a single backend and an
/// all-zeros assignment the sequence of engine calls — submissions,
/// `advance_to` instants, hand-offs, re-stages — is identical call for
/// call, so single-backend outcomes are f64-record-identical to the
/// staged path (enforced by `rust/tests/placement_parity.rs`).
#[deprecated(
    since = "0.1.0",
    note = "compose a coordinator::RunSpec and call RunSpec::run_multi"
)]
pub fn run_multi(
    jobs: &[StagedJob],
    assignment: &[usize],
    backends: &mut [&mut dyn ComputeSim],
    transfers: &mut TransferScheduler,
) -> StagedOutcome {
    RunSpec::new().run_multi(jobs, assignment, backends, transfers, None).0
}

/// [`run_multi`] with the backends fanned out across `threads` worker
/// threads under conservative time-window sync (DESIGN.md §16). Any
/// thread count is f64-record-identical to `threads = 1`, which is
/// byte-identical to the sequential loop this generalizes.
#[deprecated(
    since = "0.1.0",
    note = "compose a coordinator::RunSpec with .threads(n) and call RunSpec::run_multi"
)]
pub fn run_multi_threaded(
    jobs: &[StagedJob],
    assignment: &[usize],
    backends: &mut [&mut dyn ComputeSim],
    transfers: &mut TransferScheduler,
    threads: usize,
) -> StagedOutcome {
    RunSpec::new().threads(threads).run_multi(jobs, assignment, backends, transfers, None).0
}

/// Extra bookkeeping from a chaos-enabled co-simulation
/// ([`run_multi_chaos`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ChaosCosim {
    /// Jobs orphaned at outage onsets (a job may be orphaned more than
    /// once if its new backend fails too).
    pub orphaned: u64,
    /// Orphans re-placed onto a *different* backend (the rest re-staged
    /// to their original backend and waited out the window).
    pub re_placed: u64,
    /// Final (possibly re-placed) backend of each job.
    pub assignment: Vec<usize>,
    /// Final effective jobs (re-placement rescales compute to the new
    /// backend's speed) — what billing must fold against.
    pub effective: Vec<StagedJob>,
}

/// [`run_multi`] plus graceful degradation (DESIGN.md §15): when a
/// backend's outage onset orphans queued jobs, `replace` picks each
/// orphan's new backend and its effective (speed-rescaled) job; the loop
/// submits a fresh stage-in there and resubmits the job when it lands —
/// orphans conserve: every one re-enters exactly one backend. With
/// `replace = None`, orphans re-stage to their original backend. With no
/// outage schedules installed the engine-call sequence is identical to
/// [`run_multi`] call for call, so chaos-free runs stay
/// f64-record-identical (`rust/tests/chaos_cosim.rs`).
#[deprecated(
    since = "0.1.0",
    note = "compose a coordinator::RunSpec and call RunSpec::run_multi with a replace hook"
)]
pub fn run_multi_chaos(
    jobs: &[StagedJob],
    assignment: &[usize],
    backends: &mut [&mut dyn ComputeSim],
    transfers: &mut TransferScheduler,
    replace: Option<&mut dyn FnMut(usize, f64, usize) -> (usize, StagedJob)>,
) -> (StagedOutcome, ChaosCosim) {
    RunSpec::new().run_multi(jobs, assignment, backends, transfers, replace)
}

/// [`run_multi_chaos`] with the backends fanned out across `threads`
/// worker threads (DESIGN.md §16).
#[deprecated(
    since = "0.1.0",
    note = "compose a coordinator::RunSpec with .threads(n) and call RunSpec::run_multi"
)]
pub fn run_multi_chaos_threaded(
    jobs: &[StagedJob],
    assignment: &[usize],
    backends: &mut [&mut dyn ComputeSim],
    transfers: &mut TransferScheduler,
    replace: Option<&mut dyn FnMut(usize, f64, usize) -> (usize, StagedJob)>,
    threads: usize,
) -> (StagedOutcome, ChaosCosim) {
    RunSpec::new().threads(threads).run_multi(jobs, assignment, backends, transfers, replace)
}

/// The one staged funnel every entry point drains into
/// ([`crate::coordinator::RunSpec::run_multi`] and, through it, the
/// deprecated `run_multi*` shims). The window protocol is conservative:
/// every engine — transfers included — contributes its next-event time,
/// the minimum bounds the window, and no engine is advanced past it, so
/// results at any thread count are f64-record-identical to `threads =
/// 1` (held to account by `rust/tests/parallel_parity.rs` and all four
/// parity batteries).
pub(crate) fn run_multi_impl(
    jobs: &[StagedJob],
    assignment: &[usize],
    backends: &mut [&mut dyn ComputeSim],
    transfers: &mut TransferScheduler,
    replace: Option<&mut dyn FnMut(usize, f64, usize) -> (usize, StagedJob)>,
    threads: usize,
) -> (StagedOutcome, ChaosCosim) {
    assert_eq!(jobs.len(), assignment.len(), "one backend assignment per job");
    assert!(!backends.is_empty(), "run_multi needs at least one backend");
    if let Some(&bad) = assignment.iter().find(|&&b| b >= backends.len()) {
        panic!("assignment names backend {bad}, but only {} exist", backends.len());
    }
    let n_backends = backends.len();
    with_driver(backends, threads, |driver| {
        run_windows(driver, jobs, assignment, n_backends, transfers, replace)
    })
}

/// The windowed co-simulation loop body, written once over
/// [`WindowDriver`] so the sequential and pooled paths execute the
/// same code. Per window: arm the merged event heap from the cached
/// next-event times, advance the transfer scheduler to the bound,
/// route landed stage-ins to their backends, advance every backend to
/// the bound, and apply the backends' hand-offs to the transfer
/// scheduler **in backend index order** — the same order, with the
/// same values, as the sequential loop this was extracted from.
fn run_windows(
    driver: &mut dyn WindowDriver,
    jobs: &[StagedJob],
    assignment: &[usize],
    n_backends: usize,
    transfers: &mut TransferScheduler,
    mut replace: Option<&mut dyn FnMut(usize, f64, usize) -> (usize, StagedJob)>,
) -> (StagedOutcome, ChaosCosim) {
    let mut timings = vec![StagedTiming::default(); jobs.len()];
    // orphan re-placement may move a job and rescale its compute; the
    // SoA working columns start as bit-copies, so the chaos-free path
    // reads the same values it always did
    let mut store = JobStore::from_jobs(jobs);
    let mut assignment: Vec<usize> = assignment.to_vec();
    let mut chaos = ChaosCosim::default();
    for i in 0..store.len() {
        transfers.submit_at(stage_in_id(i), assignment[i] as u64, store.bytes_in(i), 0.0);
    }
    // transfer ids ≥ 2·jobs are re-stages; the map recovers their job
    let mut next_restage_id = (jobs.len() as u64) * 2;
    let mut restage_job: BTreeMap<u64, usize> = BTreeMap::new();
    let mut events = MergedEvents::new();
    let mut seen = 0usize;
    let mut steps: Vec<BackendStep> = Vec::with_capacity(n_backends);
    loop {
        events.arm(transfers.next_event_time());
        for &next in driver.next_events() {
            events.arm(next);
        }
        let Some(t) = events.pop_earliest() else { break };
        // every engine advances to the merged-earliest instant — the
        // hand-offs below assume a shared clock
        transfers.advance_to(t);
        // borrow, don't clone: this loop only reads the new completions
        // (it routes submissions through the driver, never `transfers`)
        let records = transfers.records();
        let new_from = seen;
        seen = records.len();
        for r in &records[new_from..] {
            let (i, stage_in) = match restage_job.get(&r.id) {
                Some(&i) => (i, true),
                None => ((r.id / 2) as usize, r.id % 2 == 0),
            };
            if stage_in {
                timings[i].stage_in_wait_s = r.queue_wait_s();
                timings[i].stage_in_s = r.transfer_s();
                driver.submit(assignment[i], i as u64, r.end_s, store.job(i));
            } else {
                timings[i].stage_out_wait_s = r.queue_wait_s();
                timings[i].stage_out_s = r.transfer_s();
                timings[i].done_s = r.end_s;
                timings[i].completed = true;
            }
        }
        // all backends advance to the window bound (possibly on worker
        // threads); their steps come back dense in backend index order,
        // and every transfer-side mutation below happens here on the
        // coordinator — in the exact sequence the sequential loop made
        driver.advance(t, &mut steps);
        for step in &steps {
            for &(id, end_s) in &step.done {
                let i = id as usize;
                timings[i].compute_end_s = end_s;
                timings[i].compute_start_s = end_s - store.compute_s(i);
                transfers.submit_at(
                    stage_out_id(i),
                    assignment[i] as u64,
                    store.bytes_out(i),
                    end_s,
                );
            }
            // timed-out attempts hand back here: their scratch inputs are
            // gone, so the retry waits on a fresh (re-contending) stage-in
            for &(id, fail_s) in &step.restage {
                let i = id as usize;
                let rid = next_restage_id;
                next_restage_id += 1;
                restage_job.insert(rid, i);
                transfers.submit_at(
                    rid,
                    assignment[i] as u64,
                    store.bytes_in(i),
                    fail_s.max(transfers.clock()),
                );
            }
            // outage onsets hand orphans back here: the planner picks a
            // surviving backend (or keeps the original), a fresh stage-in
            // goes there, and the job resubmits when it lands — if the
            // chosen backend is still inside its window, its own start
            // blocking makes the job wait the window out
            for &(id, orphan_s) in &step.orphans {
                let i = id as usize;
                chaos.orphaned += 1;
                let (to, job) = match replace.as_mut() {
                    Some(f) => f(i, orphan_s, assignment[i]),
                    None => (assignment[i], store.job(i)),
                };
                assert!(to < n_backends, "orphan re-placed onto unknown backend {to}");
                if to != assignment[i] {
                    chaos.re_placed += 1;
                }
                assignment[i] = to;
                store.set(i, job);
                let rid = next_restage_id;
                next_restage_id += 1;
                restage_job.insert(rid, i);
                transfers.submit_at(
                    rid,
                    to as u64,
                    store.bytes_in(i),
                    orphan_s.max(transfers.clock()),
                );
            }
        }
    }
    let makespan_s = timings
        .iter()
        .map(|x| x.compute_end_s)
        .fold(transfers.stats().makespan_s, f64::max);
    chaos.assignment = assignment;
    chaos.effective = store.into_jobs();
    (
        StagedOutcome {
            makespan_s,
            transfer: transfers.stats(),
            timings,
        },
        chaos,
    )
}

#[cfg(test)]
// the unit tests deliberately exercise the deprecated shims: they are
// the compatibility surface the parity batteries pin
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::netsim::scheduler::TransferScheduler;
    use crate::netsim::Env;
    use crate::slurm::ClusterSpec;

    fn jobs(n: usize, compute_s: f64) -> Vec<StagedJob> {
        (0..n)
            .map(|_| StagedJob {
                cores: 1,
                ram_gb: 1,
                compute_s,
                bytes_in: 100_000_000,
                bytes_out: 50_000_000,
            })
            .collect()
    }

    // Heap tie-break audit (DESIGN.md §16): the lane pool's future heap
    // key is (ready_s, id, duration) and its due map is keyed
    // (ready_s, id) — both total for unique ids.

    #[test]
    fn lane_future_heap_ties_start_by_id_not_submission_order() {
        let run = |ids: &[u64]| {
            let mut lanes = LanePool::new(1);
            for &id in ids {
                lanes.submit(id, 5.0, &jobs(1, 30.0)[0]);
            }
            let mut done = Vec::new();
            loop {
                let Some(t) = lanes.next_event_time() else { break };
                done.extend(lanes.advance_to(t));
            }
            done
        };
        let fwd = run(&[1, 2, 3]);
        let rev = run(&[3, 2, 1]);
        assert_eq!(fwd, rev, "insertion order must not leak through equal keys");
        let ids: Vec<u64> = fwd.iter().map(|&(id, _)| id).collect();
        assert_eq!(ids, vec![1, 2, 3], "equal ready instants start ids ascending");
    }

    #[test]
    fn merged_events_pop_duplicate_instants_once() {
        let mut events = MergedEvents::new();
        events.arm(Some(7.0));
        events.arm(Some(7.0));
        events.arm(Some(9.0));
        events.arm(None);
        // pop returns the earliest and clears the heap for the re-arm,
        // so duplicate instants across engines cannot double-fire
        assert_eq!(events.pop_earliest(), Some(7.0));
        assert_eq!(events.pop_earliest(), None);
    }

    #[test]
    fn lane_pool_caps_concurrency() {
        let js = jobs(4, 100.0);
        let mut lanes = LanePool::new(2);
        let mut transfers = TransferScheduler::for_env(Env::Local, 4, 1);
        let out = run_staged(&js, &mut lanes, &mut transfers);
        assert!(out.timings.iter().all(|t| t.completed));
        // 4 × 100 s of compute through 2 lanes needs at least two waves
        let end = out.timings.iter().map(|t| t.compute_end_s).fold(0.0, f64::max);
        assert!(end >= 200.0, "end={end}");
    }

    #[test]
    fn stage_in_compute_stage_out_overlap() {
        let js = jobs(6, 300.0);
        let mut lanes = LanePool::new(6);
        let mut transfers = TransferScheduler::for_env(Env::Local, 2, 7);
        let out = run_staged(&js, &mut lanes, &mut transfers);
        for t in &out.timings {
            assert!(t.completed);
            // compute starts only after the staged inputs land
            assert!(t.compute_start_s + 1e-6 >= t.stage_in_wait_s + t.stage_in_s);
            assert!(t.done_s + 1e-9 >= t.compute_end_s);
            assert!(t.stage_in_s > 0.0 && t.stage_out_s > 0.0);
        }
        // overlap must beat running every phase back to back
        let serial: f64 = out
            .timings
            .iter()
            .map(|t| t.stage_in_s + (t.compute_end_s - t.compute_start_s) + t.stage_out_s)
            .sum();
        assert!(
            out.makespan_s < serial,
            "phases must overlap: makespan {} vs serialized {serial}",
            out.makespan_s
        );
    }

    #[test]
    fn slurm_backend_respects_cluster_capacity() {
        let js = jobs(3, 100.0);
        let sched = Scheduler::new(ClusterSpec::small(1, 1, 4)); // one core
        let mut sim = SlurmSim::new(sched, "medflow", None);
        let mut transfers = TransferScheduler::for_env(Env::Hpc, 3, 3);
        let out = run_staged(&js, &mut sim, &mut transfers);
        assert!(out.timings.iter().all(|t| t.completed));
        // 3 × 100 s of compute through one core can never beat 300 s
        let end = out.timings.iter().map(|t| t.compute_end_s).fold(0.0, f64::max);
        assert!(end >= 300.0 - 1e-6, "end={end}");
        assert!(out.makespan_s > end - 1e-9, "copy-back extends the makespan");
    }

    #[test]
    fn copy_back_contends_with_late_stage_ins() {
        // a stream cap of 1 forces stage-ins to trickle; early jobs'
        // copy-backs are submitted while later stage-ins still queue, and
        // everything funnels through the same shared path FIFO
        let js = jobs(3, 1.0);
        let mut lanes = LanePool::new(3);
        let mut transfers = TransferScheduler::for_env(Env::Local, 1, 11);
        let out = run_staged(&js, &mut lanes, &mut transfers);
        assert!(out.timings.iter().all(|t| t.completed));
        let waits: f64 = out
            .timings
            .iter()
            .map(|t| t.stage_in_wait_s + t.stage_out_wait_s)
            .sum();
        assert!(waits > 0.0, "cap 1 must queue some transfer");
        assert_eq!(out.transfer.transfers, 6);
        assert_eq!(out.transfer.peak_streams, 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let js = jobs(5, 50.0);
        let run = || {
            let mut lanes = LanePool::new(2);
            let mut transfers = TransferScheduler::for_env(Env::Cloud, 4, 23);
            run_staged(&js, &mut lanes, &mut transfers).timings
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn empty_campaign_is_a_noop() {
        let mut lanes = LanePool::new(2);
        let mut transfers = TransferScheduler::for_env(Env::Hpc, 4, 1);
        let out = run_staged(&[], &mut lanes, &mut transfers);
        assert!(out.timings.is_empty());
        assert_eq!(out.makespan_s, 0.0);
        assert_eq!(out.transfer.transfers, 0);
    }

    #[test]
    fn wide_campaign_stays_near_linear() {
        // 5k jobs through the co-simulation in a debug-build test: the
        // pre-PR polling loop (O(n) next_event_time per event) made this
        // minutes; the merged heap + indexed engines keep it seconds.
        let js: Vec<StagedJob> = (0..5_000)
            .map(|i| StagedJob {
                cores: 1,
                ram_gb: 1,
                compute_s: 30.0 + (i % 7) as f64 * 10.0,
                bytes_in: 5_000_000,
                bytes_out: 1_000_000,
            })
            .collect();
        let mut lanes = LanePool::new(64);
        let mut transfers = TransferScheduler::for_env(Env::Local, 32, 17);
        let out = run_staged(&js, &mut lanes, &mut transfers);
        assert!(out.timings.iter().all(|t| t.completed));
        assert_eq!(out.transfer.transfers, 10_000);
    }

    use crate::faults::FaultModel;

    #[test]
    fn zero_rate_injection_reproduces_fault_free_cosim() {
        let js = jobs(8, 120.0);
        let run = |inject: bool| {
            let mut lanes = LanePool::new(3);
            let mut transfers = TransferScheduler::for_env(Env::Local, 2, 19);
            if inject {
                lanes.set_faults(Injection::new(FaultModel::none(), 3, 77).with_parked_timeouts());
                transfers.set_faults(Injection::new(FaultModel::none(), 3, 78));
            }
            run_staged(&js, &mut lanes, &mut transfers)
        };
        let plain = run(false);
        let injected = run(true);
        assert_eq!(plain.timings, injected.timings, "zero-rate injection must be a no-op");
        assert_eq!(plain.makespan_s, injected.makespan_s);
        assert_eq!(plain.transfer, injected.transfer);
    }

    #[test]
    fn timed_out_attempts_restage_through_the_transfer_path() {
        // every attempt times out: each of the 2 jobs runs 3 attempts
        // (initial + 2 parked retries), each retry preceded by a fresh
        // stage-in that re-contends on the shared path, then aborts
        let js = jobs(2, 100.0);
        let mut lanes = LanePool::new(2);
        lanes.set_faults(
            Injection::new(
                FaultModel {
                    p_timeout: 1.0,
                    ..FaultModel::none()
                },
                2,
                5,
            )
            .with_backoff(0.0)
            .with_parked_timeouts(),
        );
        let mut transfers = TransferScheduler::for_env(Env::Local, 4, 21);
        let out = run_staged(&js, &mut lanes, &mut transfers);
        assert!(out.timings.iter().all(|t| !t.completed), "no job survives");
        // 3 stage-ins per job (ids 0,2 then restage ids ≥ 4), no copy-backs
        assert_eq!(out.transfer.transfers, 6);
        assert!(transfers.records().iter().all(|r| r.id % 2 == 0 || r.id >= 4));
        assert_eq!(lanes.fault_events().len(), 6);
        assert_eq!(lanes.aborted_ids().len(), 2);
        assert_eq!(
            lanes.fault_events().iter().filter(|e| e.action == FaultAction::Parked).count(),
            4,
            "two parked retries per job"
        );
        // each timeout wasted the full allocation
        assert!(lanes.fault_events().iter().all(|e| e.wasted_s == 100.0));
    }

    #[test]
    fn requeued_compute_failures_stay_inside_the_backend() {
        // node failures requeue in-engine: no extra stage-ins appear
        let js = jobs(3, 60.0);
        let mut lanes = LanePool::new(3);
        lanes.set_faults(
            Injection::new(
                FaultModel {
                    p_node: 1.0,
                    ..FaultModel::none()
                },
                1,
                9,
            )
            .with_backoff(5.0),
        );
        let mut transfers = TransferScheduler::for_env(Env::Local, 4, 23);
        let out = run_staged(&js, &mut lanes, &mut transfers);
        assert!(out.timings.iter().all(|t| !t.completed));
        assert_eq!(out.transfer.transfers, 3, "stage-ins only, no restages, no copy-backs");
        assert_eq!(lanes.fault_events().len(), 6, "two attempts per job");
        assert_eq!(lanes.aborted_ids().len(), 3);
        assert_eq!(lanes.wasted_alloc_s(), 6.0 * 30.0, "each attempt wastes half of 60 s");
    }

    #[test]
    fn moderate_faults_complete_with_retries_and_extend_makespan() {
        let js = jobs(30, 90.0);
        let run = |faulty: bool| {
            let mut lanes = LanePool::new(4);
            let mut transfers = TransferScheduler::for_env(Env::Local, 4, 29);
            if faulty {
                lanes.set_faults(
                    Injection::new(FaultModel::harsh().compute_only(), 5, 31).with_backoff(10.0),
                );
                transfers.set_faults(Injection::new(FaultModel::harsh().transfer_only(), 5, 33));
            }
            let out = run_staged(&js, &mut lanes, &mut transfers);
            (out, lanes.aborted_ids().len(), lanes.fault_events().len())
        };
        let (clean, clean_aborts, clean_events) = run(false);
        let (faulty, aborts, events) = run(true);
        assert_eq!(clean_aborts + clean_events, 0);
        assert!(clean.timings.iter().all(|t| t.completed));
        let completed = faulty.timings.iter().filter(|t| t.completed).count();
        assert_eq!(completed + aborts, 30, "jobs either complete or abort");
        assert!(events > 0, "harsh rates over 30 jobs must fail some attempts");
        assert!(
            faulty.makespan_s > clean.makespan_s,
            "retries must extend the campaign: {} vs {}",
            faulty.makespan_s,
            clean.makespan_s
        );
    }

    #[test]
    fn fault_cosim_deterministic_given_seed() {
        let js = jobs(12, 45.0);
        let run = || {
            let mut lanes = LanePool::new(3);
            lanes.set_faults(
                Injection::new(FaultModel::harsh().compute_only(), 3, 61)
                    .with_backoff(2.0)
                    .with_parked_timeouts(),
            );
            let mut transfers = TransferScheduler::for_env(Env::Local, 2, 63);
            transfers.set_faults(Injection::new(FaultModel::harsh().transfer_only(), 3, 65));
            let out = run_staged(&js, &mut lanes, &mut transfers);
            (out.timings, lanes.fault_events().to_vec(), transfers.fault_events().to_vec())
        };
        assert_eq!(run(), run());
    }

    use crate::netsim::scheduler::Topology;

    fn window(mode: OutageMode, start_s: f64, end_s: f64) -> OutageWindow {
        OutageWindow { mode, start_s, end_s }
    }

    #[test]
    fn empty_lane_outage_schedule_is_bit_identical() {
        let js = jobs(6, 80.0);
        let run = |chaos: bool| {
            let mut lanes = LanePool::new(2);
            if chaos {
                lanes.set_outages(Vec::new(), 30.0);
            }
            let mut transfers = TransferScheduler::for_env(Env::Local, 3, 41);
            run_staged(&js, &mut lanes, &mut transfers)
        };
        let plain = run(false);
        let chaos = run(true);
        assert_eq!(plain.timings, chaos.timings, "empty outage schedule must be a no-op");
        assert_eq!(plain.makespan_s, chaos.makespan_s);
        assert_eq!(plain.transfer, chaos.transfer);
    }

    #[test]
    fn lane_drain_onset_orphans_queue_and_blocks_starts() {
        let j = StagedJob {
            cores: 1,
            ram_gb: 1,
            compute_s: 60.0,
            bytes_in: 0,
            bytes_out: 0,
        };
        let mut lanes = LanePool::new(1);
        lanes.set_outages(vec![window(OutageMode::Drain, 50.0, 100.0)], 0.0);
        lanes.submit(0, 0.0, &j);
        lanes.submit(1, 10.0, &j);
        let done = lanes.advance_to(300.0);
        // job 0 was already running at the onset: Drain lets it finish
        assert_eq!(done, vec![(0, 60.0)]);
        // job 1 was queued behind it: released back to the planner
        assert_eq!(lanes.take_orphans(), vec![(1, 50.0)]);
        assert_eq!(lanes.outage_killed(), 0);
        assert_eq!(lanes.outage_wasted_s(), 0.0);
    }

    #[test]
    fn lane_down_onset_kills_running_attempts_and_requeues() {
        let j = StagedJob {
            cores: 1,
            ram_gb: 1,
            compute_s: 100.0,
            bytes_in: 0,
            bytes_out: 0,
        };
        let mut lanes = LanePool::new(1);
        lanes.set_outages(vec![window(OutageMode::Down, 30.0, 40.0)], 5.0);
        lanes.submit(0, 0.0, &j);
        let done = lanes.advance_to(500.0);
        // killed at 30 (30 s wasted), requeued at 35 — still inside the
        // window — so the retry starts at the window end and runs in full
        assert_eq!(done, vec![(0, 140.0)]);
        assert_eq!(lanes.outage_killed(), 1);
        assert_eq!(lanes.outage_wasted_s(), 30.0);
        assert!(lanes.take_orphans().is_empty(), "running attempts requeue locally");
    }

    #[test]
    fn lane_outage_cosim_is_deterministic() {
        let js = jobs(10, 70.0);
        let run = || {
            let mut lanes = LanePool::new(2);
            lanes.set_outages(
                vec![
                    window(OutageMode::Down, 120.0, 180.0),
                    window(OutageMode::Drain, 400.0, 450.0),
                ],
                10.0,
            );
            let mut transfers = TransferScheduler::for_env(Env::Local, 3, 43);
            let out = run_staged(&js, &mut lanes, &mut transfers);
            (out.timings, lanes.outage_killed(), lanes.outage_wasted_s())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn chaos_orphans_re_place_onto_a_surviving_backend() {
        // backend 0 drains mid-campaign; the orphaned queued job re-places
        // onto backend 1 via a fresh stage-in and completes there
        let js: Vec<StagedJob> = (0..2)
            .map(|_| StagedJob {
                cores: 1,
                ram_gb: 1,
                compute_s: 100.0,
                bytes_in: 1_000,
                bytes_out: 1_000,
            })
            .collect();
        let mut a = LanePool::new(1);
        a.set_outages(vec![window(OutageMode::Drain, 30.0, 10_000.0)], 0.0);
        let mut b = LanePool::new(1);
        let mut backends: Vec<&mut dyn ComputeSim> = vec![&mut a, &mut b];
        let topo = Topology::of(Env::Local)
            .with_host_stream_cap(0, 4)
            .with_host_stream_cap(1, 4);
        let mut transfers = TransferScheduler::new(topo, 47);
        let mut replace = |i: usize, _orphan_s: f64, _from: usize| (1usize, js[i]);
        let (out, chaos) =
            run_multi_chaos(&js, &[0, 0], &mut backends, &mut transfers, Some(&mut replace));
        assert_eq!(chaos.orphaned, 1);
        assert_eq!(chaos.re_placed, 1);
        assert_eq!(chaos.assignment, vec![0, 1]);
        assert!(out.timings.iter().all(|t| t.completed), "every orphan re-enters a backend");
        // 2 stage-ins + 1 re-stage + 2 copy-backs
        assert_eq!(out.transfer.transfers, 5);
    }
}
