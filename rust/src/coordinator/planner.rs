//! Campaign planner: topologically orders the 16-pipeline registry by
//! their prior-pipeline dependencies and runs a full processing sweep over
//! a dataset — the "run everything new data is eligible for" workflow a
//! curation team executes after each data pull (paper §2.1: new scans are
//! pulled every 6–12 months and must flow through all pipelines).

use anyhow::Result;

use crate::bids::BidsDataset;
use crate::coordinator::{CampaignConfig, CampaignReport, Coordinator, SubmitTarget};
use crate::pipeline::{registry, InputReq, PipelineSpec};

/// Dependency of a pipeline, if any.
pub fn prior_of(spec: &PipelineSpec) -> Option<&'static str> {
    match spec.input {
        InputReq::T1wAndPrior(p) | InputReq::DwiAndPrior(p) => Some(p),
        _ => None,
    }
}

/// Topological order of the pipeline registry (priors before dependents).
/// The registry's dependency graph is a forest of depth ≤ 1 (checked by a
/// pipeline unit test), so a two-bucket sort is exact — but we implement
/// Kahn's algorithm anyway so deeper chains keep working.
pub fn plan_order() -> Vec<PipelineSpec> {
    let all = registry();
    let mut in_deg: Vec<usize> = all
        .iter()
        .map(|p| usize::from(prior_of(p).is_some()))
        .collect();
    let mut order = Vec::with_capacity(all.len());
    let mut ready: Vec<usize> = (0..all.len()).filter(|&i| in_deg[i] == 0).collect();
    while let Some(i) = ready.pop() {
        order.push(all[i].clone());
        for (j, q) in all.iter().enumerate() {
            if prior_of(q) == Some(all[i].name) {
                in_deg[j] -= 1;
                if in_deg[j] == 0 {
                    ready.push(j);
                }
            }
        }
    }
    assert_eq!(order.len(), all.len(), "pipeline dependency cycle");
    order
}

/// Summary of a full sweep.
#[derive(Debug)]
pub struct SweepReport {
    pub campaigns: Vec<CampaignReport>,
}

impl SweepReport {
    pub fn total_completed(&self) -> usize {
        self.campaigns.iter().map(|c| c.completed).sum()
    }

    pub fn total_cost_dollars(&self) -> f64 {
        self.campaigns.iter().map(|c| c.total_cost_dollars).sum()
    }

    /// Sum of campaign makespans (campaigns run back-to-back: a dependent
    /// pipeline cannot start before its prior's outputs are copied back).
    pub fn total_makespan_s(&self) -> f64 {
        self.campaigns.iter().map(|c| c.makespan_s).sum()
    }
}

/// Run every pipeline over the dataset in dependency order.
pub fn run_sweep(
    coord: &mut Coordinator<'_>,
    ds: &BidsDataset,
    target: SubmitTarget,
    cfg: &CampaignConfig,
) -> Result<SweepReport> {
    let mut campaigns = Vec::new();
    for spec in plan_order() {
        campaigns.push(coord.run_campaign(ds, spec.name, target, cfg)?);
    }
    Ok(SweepReport { campaigns })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::archive::{Archive, SecurityTier};
    use crate::container::ContainerArchive;
    use crate::slurm::ClusterSpec;
    use crate::workload::{ingest_cohort, SynthCohort};
    use std::path::PathBuf;

    #[test]
    fn plan_order_respects_dependencies() {
        let order = plan_order();
        assert_eq!(order.len(), 16);
        let pos: std::collections::HashMap<&str, usize> =
            order.iter().enumerate().map(|(i, p)| (p.name, i)).collect();
        for p in &order {
            if let Some(dep) = prior_of(p) {
                assert!(pos[dep] < pos[p.name], "{dep} must precede {}", p.name);
            }
        }
    }

    #[test]
    fn sweep_unlocks_dependents_in_one_pass() {
        let root = std::env::temp_dir().join(format!("medflow_sweep_{}", std::process::id()));
        std::fs::create_dir_all(&root).unwrap();
        let mut archive = Archive::at(&root.join("store")).unwrap();
        let cohort = SynthCohort {
            name: "SWEEP".into(),
            participants: 2,
            sessions: 3,
            tier: SecurityTier::General,
        };
        let ds = ingest_cohort(&mut archive, &root.join("bids"), &cohort, 8, 19).unwrap();
        let containers = ContainerArchive::open(&root.join("containers")).unwrap();
        let mut coord = Coordinator::new(archive, containers, None);
        coord.cluster = ClusterSpec::small(8, 16, 128);
        let sweep =
            run_sweep(&mut coord, &ds, SubmitTarget::Hpc, &CampaignConfig::default()).unwrap();
        assert_eq!(sweep.campaigns.len(), 16);
        // dependents completed in the SAME sweep as their priors
        let by_name: std::collections::HashMap<&str, &CampaignReport> = sweep
            .campaigns
            .iter()
            .map(|c| (c.pipeline.as_str(), c))
            .collect();
        assert_eq!(
            by_name["tractseg"].completed, by_name["prequal"].completed,
            "tractseg must run for every prequal'd session"
        );
        assert_eq!(by_name["brain_age"].completed, by_name["freesurfer"].completed);
        assert!(sweep.total_completed() > 0);
        assert!(sweep.total_cost_dollars() > 0.0);
        let _ = PathBuf::new();
        std::fs::remove_dir_all(&root).unwrap();
    }
}
