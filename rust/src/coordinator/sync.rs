//! Conservative time-window synchronization for parallel compute
//! engines (DESIGN.md §16).
//!
//! This is the **only** engine module allowed to hold threading
//! primitives: DL005 (`thread-spawn`) gates every other engine file,
//! and the file-level allow below is the containment boundary ROADMAP
//! item 2 called for. Everything here preserves the sequential replay
//! contract by construction:
//!
//! - The window bound `t` is computed by the caller as the minimum
//!   next-event time across the transfer scheduler and every backend
//!   (classic null-message-free conservative PDES lookahead). No
//!   backend is ever advanced past `t`, so no backend can observe —
//!   or miss — a cross-engine interaction inside a window.
//! - Backends never read the transfer scheduler or each other; their
//!   only inputs are `submit` calls and the window bound. Each worker
//!   therefore replays exactly the call sequence the sequential loop
//!   would have made: the per-worker command channel is FIFO, and the
//!   coordinator sends all of a window's `Submit`s before its
//!   `Advance`.
//! - Results merge in **backend index order, never thread arrival
//!   order**: `advance` slots each worker's reply by backend index and
//!   the caller consumes the dense `Vec<BackendStep>` 0..n. The f64s
//!   inside are bit-copies of what the engine computed; the merge adds
//!   no arithmetic.
//! - The next-event cache refreshed at the end of window N equals a
//!   live read at the top of window N+1, because the driver's `submit`
//!   only runs mid-window (before `advance`) — so caching it on the
//!   worker side is observation-equivalent to the sequential arm.
//!
//! `rust/tests/parallel_parity.rs` and the four parity batteries hold
//! the proof to account: any thread count must be f64-record-identical
//! to `--threads 1`, which is byte-identical to the pre-parallel loop.
//
// lint:allow-file(thread-spawn) — the conservative window-sync layer
// itself; every other engine file stays gated (DESIGN.md §16).

use std::sync::mpsc;

use crate::coordinator::staged::{ComputeSim, StagedJob};

/// What one backend produced inside one window: completions, parked
/// re-stages, outage orphans, and its cumulative abort count.
#[derive(Debug, Default)]
pub(crate) struct BackendStep {
    /// `(job id, compute end)` pairs completed by the window bound.
    pub done: Vec<(u64, f64)>,
    /// `(job id, fail time)` pairs whose timeout wiped local scratch.
    pub restage: Vec<(u64, f64)>,
    /// `(job id, onset time)` pairs orphaned by an outage onset.
    pub orphans: Vec<(u64, f64)>,
    /// Cumulative aborted-job count (tenancy frees admission slots off
    /// the delta between windows).
    pub aborted: usize,
}

/// Uniform driver interface over N compute backends — sequential
/// in-place or fanned out one-engine-per-worker — so the co-simulation
/// loops in [`crate::coordinator::staged`] and
/// [`crate::coordinator::tenancy`] are written once.
///
/// Protocol per window: read [`next_events`](Self::next_events) to arm
/// the merged event queue, [`submit`](Self::submit) any jobs whose
/// stage-ins landed, then [`advance`](Self::advance) every backend to
/// the window bound and consume the steps in backend index order.
pub(crate) trait WindowDriver {
    /// Cached per-backend next-event times, valid at the top of a
    /// window (refreshed by [`advance`](Self::advance)).
    fn next_events(&self) -> &[Option<f64>];
    /// Route one submission to `backend`.
    fn submit(&mut self, backend: usize, id: u64, ready_s: f64, job: StagedJob);
    /// Advance every backend to `t`; `out` is filled with one
    /// [`BackendStep`] per backend, in backend index order.
    fn advance(&mut self, t: f64, out: &mut Vec<BackendStep>);
}

/// The `--threads 1` driver: drives the borrowed engines inline, in
/// index order, exactly as the pre-parallel loop did.
struct SeqDriver<'a, 'b> {
    backends: &'a mut [&'b mut dyn ComputeSim],
    next: Vec<Option<f64>>,
}

impl WindowDriver for SeqDriver<'_, '_> {
    fn next_events(&self) -> &[Option<f64>] {
        &self.next
    }

    fn submit(&mut self, backend: usize, id: u64, ready_s: f64, job: StagedJob) {
        self.backends[backend].submit(id, ready_s, &job);
    }

    fn advance(&mut self, t: f64, out: &mut Vec<BackendStep>) {
        out.clear();
        for (k, backend) in self.backends.iter_mut().enumerate() {
            let done = backend.advance_to(t);
            let step = BackendStep {
                done,
                restage: backend.take_restage(),
                orphans: backend.take_orphans(),
                aborted: backend.aborted_count(),
            };
            self.next[k] = backend.next_event_time();
            out.push(step);
        }
    }
}

/// One window command to a worker. The per-worker channel is FIFO, so
/// a window's `Submit`s always precede its `Advance` — the worker
/// replays the sequential per-engine call order exactly.
enum Cmd {
    Submit {
        backend: usize,
        id: u64,
        ready_s: f64,
        job: StagedJob,
    },
    Advance {
        t: f64,
    },
}

/// One backend's window result plus its refreshed next-event time,
/// tagged with the backend index for deterministic merging.
struct WorkerStep {
    step: BackendStep,
    next: Option<f64>,
}

/// Worker body: owns a shard of backends for the whole run and serves
/// window commands until the coordinator hangs up.
fn worker_loop(
    mut shard: Vec<(usize, &mut dyn ComputeSim)>,
    rx: mpsc::Receiver<Cmd>,
    tx: mpsc::Sender<(usize, WorkerStep)>,
) {
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Submit {
                backend,
                id,
                ready_s,
                job,
            } => {
                let sim = shard
                    .iter_mut()
                    .find(|(k, _)| *k == backend)
                    .expect("submission routed to a worker that does not own the backend");
                sim.1.submit(id, ready_s, &job);
            }
            Cmd::Advance { t } => {
                for (k, sim) in shard.iter_mut() {
                    let done = sim.advance_to(t);
                    let step = BackendStep {
                        done,
                        restage: sim.take_restage(),
                        orphans: sim.take_orphans(),
                        aborted: sim.aborted_count(),
                    };
                    let next = sim.next_event_time();
                    if tx.send((*k, WorkerStep { step, next })).is_err() {
                        return; // coordinator gone; unwind quietly
                    }
                }
            }
        }
    }
}

/// The `--threads N` driver: backends are sharded across workers by
/// `index % workers`; submissions route to the owning worker, and
/// `advance` broadcasts the window bound then collects exactly one
/// reply per backend, slotted by index.
struct PoolDriver {
    cmd_txs: Vec<mpsc::Sender<Cmd>>,
    res_rx: mpsc::Receiver<(usize, WorkerStep)>,
    next: Vec<Option<f64>>,
    n_backends: usize,
    /// Reply slots reused across windows (index-ordered merge scratch).
    slots: Vec<Option<BackendStep>>,
}

impl PoolDriver {
    fn worker_of(&self, backend: usize) -> usize {
        backend % self.cmd_txs.len()
    }
}

impl WindowDriver for PoolDriver {
    fn next_events(&self) -> &[Option<f64>] {
        &self.next
    }

    fn submit(&mut self, backend: usize, id: u64, ready_s: f64, job: StagedJob) {
        self.cmd_txs[self.worker_of(backend)]
            .send(Cmd::Submit {
                backend,
                id,
                ready_s,
                job,
            })
            .expect("worker thread died mid-run");
    }

    fn advance(&mut self, t: f64, out: &mut Vec<BackendStep>) {
        for tx in &self.cmd_txs {
            tx.send(Cmd::Advance { t }).expect("worker thread died mid-run");
        }
        self.slots.iter_mut().for_each(|s| *s = None);
        for _ in 0..self.n_backends {
            let (k, ws) = self
                .res_rx
                .recv()
                .expect("worker thread died before finishing the window");
            debug_assert!(self.slots[k].is_none(), "duplicate reply for backend {k}");
            self.next[k] = ws.next;
            self.slots[k] = Some(ws.step);
        }
        out.clear();
        for slot in &mut self.slots {
            out.push(slot.take().expect("missing backend reply"));
        }
    }
}

/// Run `f` against a [`WindowDriver`] over `backends`.
///
/// `threads` ≤ 1 (or a single backend) drives the engines inline on
/// the calling thread — byte-identical to the pre-parallel loop. More
/// threads shard the backends across `min(threads, backends)` scoped
/// workers; the scope joins them before returning, so no thread
/// outlives the borrow.
pub(crate) fn with_driver<R>(
    backends: &mut [&mut dyn ComputeSim],
    threads: usize,
    f: impl FnOnce(&mut dyn WindowDriver) -> R,
) -> R {
    let n = backends.len();
    let workers = if threads <= 1 { 1 } else { threads.min(n) };
    // The initial cache is read before any worker exists: outage onsets
    // make next_event_time non-None even on an idle engine, so this
    // read must see the pre-run configuration.
    let next: Vec<Option<f64>> = backends.iter().map(|b| b.next_event_time()).collect();
    if workers <= 1 {
        let mut driver = SeqDriver { backends, next };
        return f(&mut driver);
    }
    std::thread::scope(|scope| {
        let (res_tx, res_rx) = mpsc::channel();
        let mut shards: Vec<Vec<(usize, &mut dyn ComputeSim)>> =
            (0..workers).map(|_| Vec::new()).collect();
        for (k, backend) in backends.iter_mut().enumerate() {
            shards[k % workers].push((k, &mut **backend));
        }
        let mut cmd_txs = Vec::with_capacity(workers);
        for shard in shards {
            let (tx, rx) = mpsc::channel();
            cmd_txs.push(tx);
            let res_tx = res_tx.clone();
            scope.spawn(move || worker_loop(shard, rx, res_tx));
        }
        drop(res_tx);
        let mut driver = PoolDriver {
            cmd_txs,
            res_rx,
            next,
            n_backends: n,
            slots: (0..n).map(|_| None).collect(),
        };
        f(&mut driver)
        // Dropping the driver closes the command channels; workers'
        // recv() errors out and they exit, then the scope joins them.
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::staged::LanePool;

    fn pool(lanes: usize) -> LanePool {
        LanePool::new(lanes)
    }

    fn job(compute_s: f64) -> StagedJob {
        StagedJob {
            cores: 1,
            ram_gb: 4,
            compute_s,
            bytes_in: 1_000,
            bytes_out: 500,
        }
    }

    /// Drive the same 3-backend workload through the sequential and
    /// pooled drivers and assert bit-identical steps and caches.
    #[test]
    fn pooled_driver_matches_sequential_bit_exactly() {
        let run = |threads: usize| -> (Vec<Vec<(u64, f64)>>, Vec<Vec<Option<f64>>>) {
            let mut a = pool(1);
            let mut b = pool(2);
            let mut c = pool(1);
            let mut backends: Vec<&mut dyn ComputeSim> = vec![&mut a, &mut b, &mut c];
            with_driver(&mut backends, threads, |driver| {
                let mut done = Vec::new();
                let mut nexts = Vec::new();
                // window 1: one job per backend, staggered readies
                driver.submit(0, 0, 0.0, job(10.0));
                driver.submit(1, 1, 1.0, job(20.0));
                driver.submit(2, 2, 2.0, job(30.0));
                let mut out = Vec::new();
                for t in [5.0_f64, 12.0, 22.0, 40.0] {
                    driver.advance(t, &mut out);
                    done.push(out.iter().flat_map(|s| s.done.iter().copied()).collect());
                    nexts.push(driver.next_events().to_vec());
                }
                (done, nexts)
            })
        };
        let (done1, next1) = run(1);
        for threads in [2usize, 3, 8] {
            let (done_n, next_n) = run(threads);
            assert_eq!(done1, done_n, "threads={threads}");
            for (a, b) in next1.iter().flatten().zip(next_n.iter().flatten()) {
                assert_eq!(
                    a.map(f64::to_bits),
                    b.map(f64::to_bits),
                    "next-event cache diverged at threads={threads}"
                );
            }
        }
    }

    /// Steps must arrive in backend index order even when later-indexed
    /// backends finish their windows first.
    #[test]
    fn merge_order_is_backend_index_not_arrival() {
        let mut a = pool(1);
        let mut b = pool(1);
        let mut backends: Vec<&mut dyn ComputeSim> = vec![&mut a, &mut b];
        with_driver(&mut backends, 2, |driver| {
            // backend 1 gets the short job: it will finish first in
            // wall-clock, but must still merge second.
            driver.submit(0, 0, 0.0, job(50.0));
            driver.submit(1, 1, 0.0, job(1.0));
            let mut out = Vec::new();
            driver.advance(100.0, &mut out);
            assert_eq!(out.len(), 2);
            assert_eq!(out[0].done, vec![(0, 50.0)]);
            assert_eq!(out[1].done, vec![(1, 1.0)]);
        });
    }

    /// `threads` beyond the backend count must clamp, not spawn idle
    /// workers; zero threads means sequential.
    #[test]
    fn thread_count_clamps_to_backends() {
        for threads in [0usize, 1, 7] {
            let mut a = pool(1);
            let mut backends: Vec<&mut dyn ComputeSim> = vec![&mut a];
            let done = with_driver(&mut backends, threads, |driver| {
                driver.submit(0, 0, 0.0, job(3.0));
                let mut out = Vec::new();
                driver.advance(10.0, &mut out);
                out[0].done.clone()
            });
            assert_eq!(done, vec![(0, 3.0)], "threads={threads}");
        }
    }
}
