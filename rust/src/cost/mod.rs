//! Cost accounting (paper §2.2, §3, Table 1): ACCRE core-hours, AWS
//! on-demand instances, amortized workstations, ACCRE vs Glacier storage.
//! All constants come from the paper (and its cited pricing pages).

pub mod planner;

use crate::netsim::Env;
use crate::util::units::{GB, TB};

/// ACCRE on-demand compute: $84/core/year (paper §2.2).
pub const ACCRE_DOLLARS_PER_CORE_YEAR: f64 = 84.0;

/// AWS t2.xlarge (4 vCPU, 16 GB): $0.1856/hr (paper Table 1, ref 56).
pub const AWS_T2_XLARGE_PER_HOUR: f64 = 0.1856;

/// Research workstation: ~$4000, 5-year life (paper Table 1 caption).
pub const WORKSTATION_DOLLARS: f64 = 4000.0;
pub const WORKSTATION_LIFE_YEARS: f64 = 5.0;

/// ACCRE backed-up storage: $180/TB/year (paper §2.2).
pub const ACCRE_STORAGE_PER_TB_YEAR: f64 = 180.0;

/// Amazon Glacier Deep Archive: $0.0036/GB/month (paper §2.2, ref 54).
pub const GLACIER_PER_GB_MONTH: f64 = 0.0036;

const HOURS_PER_YEAR: f64 = 8760.0;

/// $/hour to hold one job slot (a 16 GB single-instance allocation, the
/// Table 1 comparison unit) in each environment.
pub fn instance_hourly_rate(env: Env) -> f64 {
    match env {
        // Table 1 compares one 16 GB instance; ACCRE's unit is the core.
        Env::Hpc => ACCRE_DOLLARS_PER_CORE_YEAR / HOURS_PER_YEAR,
        Env::Cloud => AWS_T2_XLARGE_PER_HOUR,
        // One workstation amortized over its life, one job per workstation
        // (paper's stated assumption).
        Env::Local => WORKSTATION_DOLLARS / (WORKSTATION_LIFE_YEARS * HOURS_PER_YEAR),
    }
}

/// Direct cost of holding a slot for `minutes` in `env`.
///
/// A real `assert!`, not `debug_assert!` (same pattern as the
/// `Rng::below(0)` fix): negative minutes used to price as *negative
/// dollars* and silently shrink campaign totals far from the bad
/// caller; a sign bug must fail here, at the billing boundary.
pub fn compute_cost(env: Env, minutes: f64) -> f64 {
    assert!(
        minutes >= 0.0,
        "compute_cost: negative allocation ({minutes} min) would bill negative dollars — \
         durations must be ≥ 0"
    );
    instance_hourly_rate(env) * minutes / 60.0
}

/// Slot cost of one staged-campaign job: the slot is held for the
/// modeled compute plus the **scheduler-observed** transfer seconds —
/// the contended wire times reported by
/// [`crate::netsim::scheduler::TransferScheduler`], not the independent
/// single-stream samples of `NetProfile::transfer_time`. Queue wait in
/// the transfer scheduler does not hold the slot (the job has not been
/// allocated yet while its inputs wait to stream).
///
/// Under in-engine fault injection (DESIGN.md §11) `compute_minutes` is
/// the *effective* figure: the coordinator bills every failed attempt's
/// wasted allocation (`FailureMode::wasted_fraction()` of the nominal
/// duration, per attempt) into it before pricing, so retries pay the
/// slot rate — the paper's §4 overrun, itemized per job. Wasted
/// *transfer* seconds are deliberately not billed here: a checksum
/// retry holds no compute allocation (stage-in precedes the slot,
/// copy-back follows its release); they surface in the campaign's
/// fault telemetry instead.
pub fn staged_job_cost(env: Env, compute_minutes: f64, transfer_s: f64) -> f64 {
    assert!(
        compute_minutes >= 0.0 && transfer_s >= 0.0,
        "staged_job_cost: negative time ({compute_minutes} min compute, {transfer_s} s \
         transfer) would bill negative dollars — durations must be ≥ 0"
    );
    compute_cost(env, compute_minutes + transfer_s / 60.0)
}

/// Yearly cost of `bytes` on ACCRE backed-up storage.
pub fn accre_storage_cost_per_year(bytes: u64) -> f64 {
    bytes as f64 / TB as f64 * ACCRE_STORAGE_PER_TB_YEAR
}

/// Monthly cost of `bytes` in Glacier Deep Archive.
pub fn glacier_cost_per_month(bytes: u64) -> f64 {
    bytes as f64 / GB as f64 * GLACIER_PER_GB_MONTH
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hourly_rates_match_table1() {
        // Table 1: HPC 0.0096, cloud 0.1856, local 0.0913 $/hr
        assert!((instance_hourly_rate(Env::Hpc) - 0.0096).abs() < 0.0001);
        assert!((instance_hourly_rate(Env::Cloud) - 0.1856).abs() < 1e-9);
        assert!((instance_hourly_rate(Env::Local) - 0.0913).abs() < 0.0001);
    }

    #[test]
    fn freesurfer_campaign_costs_match_table1() {
        // Table 1 bottom row: 6 scans × mean runtime → $0.36 / $6.59 / $3.53
        let hpc = 6.0 * compute_cost(Env::Hpc, 375.5);
        let cloud = 6.0 * compute_cost(Env::Cloud, 355.2);
        let local = 6.0 * compute_cost(Env::Local, 386.0);
        assert!((hpc - 0.36).abs() < 0.01, "hpc={hpc}");
        assert!((cloud - 6.59).abs() < 0.02, "cloud={cloud}");
        assert!((local - 3.53).abs() < 0.02, "local={local}");
    }

    #[test]
    fn cloud_roughly_20x_hpc() {
        let ratio = compute_cost(Env::Cloud, 355.2) / compute_cost(Env::Hpc, 375.5);
        assert!((15.0..25.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn storage_costs_match_paper() {
        // §2.2: 400 TB on ACCRE = $72,000/yr
        assert!((accre_storage_cost_per_year(400 * TB) - 72_000.0).abs() < 1.0);
        // Glacier is far cheaper per year for the same bytes
        let glacier_yr = glacier_cost_per_month(400 * TB) * 12.0;
        assert!(glacier_yr < 72_000.0 / 3.0, "glacier={glacier_yr}");
    }

    #[test]
    fn staged_cost_adds_contended_transfer_seconds() {
        for env in Env::all() {
            assert_eq!(staged_job_cost(env, 100.0, 0.0), compute_cost(env, 100.0));
            // 10 minutes of contended transfer cost exactly 10 slot-minutes
            let with_transfer = staged_job_cost(env, 100.0, 600.0);
            assert!((with_transfer - compute_cost(env, 110.0)).abs() < 1e-12);
            assert!(with_transfer > staged_job_cost(env, 100.0, 60.0));
        }
    }

    #[test]
    fn zero_time_zero_cost() {
        for env in Env::all() {
            assert_eq!(compute_cost(env, 0.0), 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "compute_cost: negative allocation")]
    fn negative_minutes_panic_instead_of_billing_negative_dollars() {
        let _ = compute_cost(Env::Hpc, -1.0);
    }

    #[test]
    #[should_panic(expected = "staged_job_cost: negative time")]
    fn negative_transfer_seconds_panic_instead_of_discounting() {
        let _ = staged_job_cost(Env::Cloud, 10.0, -0.5);
    }
}
