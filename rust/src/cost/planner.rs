//! Paper-scale cost projection (§4: "processing tens of thousands of MRI
//! scans … through 16 different processing pipelines can become a huge
//! financial sink"). Projects a full-catalog processing campaign's
//! core-hours and dollars per environment, with and without the fault
//! overrun — the planning tool a lab would actually consult before
//! committing to a platform.

use crate::cost::compute_cost;
use crate::faults::{expected_overrun, FaultModel};
use crate::netsim::Env;
use crate::pipeline::{registry, InputReq, PipelineSpec};
use crate::util::units::checked_u64;
use crate::workload::{catalog, DatasetCatalogEntry};

/// Projection for one pipeline over the full catalog.
#[derive(Debug, Clone)]
pub struct PipelineProjection {
    pub pipeline: &'static str,
    pub eligible_sessions: u64,
    pub core_hours: f64,
    pub dollars_hpc: f64,
    pub dollars_cloud: f64,
}

/// Catalog-wide projection.
#[derive(Debug, Clone)]
pub struct CampaignProjection {
    pub per_pipeline: Vec<PipelineProjection>,
    pub overrun_factor: f64,
}

/// Fraction of sessions carrying each modality (matches the synthetic
/// cohort generator's rates — 90% T1w, 60% DWI).
const P_T1: f64 = 0.9;
const P_DWI: f64 = 0.6;

fn eligible_fraction(input: &InputReq) -> f64 {
    match input {
        InputReq::T1w => P_T1,
        InputReq::Dwi => P_DWI,
        InputReq::T1wAndDwi => P_T1 * P_DWI,
        // dependents run wherever the prior ran
        InputReq::T1wAndPrior(_) => P_T1,
        InputReq::DwiAndPrior(_) => P_DWI,
    }
}

fn project_pipeline(
    spec: &PipelineSpec,
    total_sessions: u64,
    overrun: f64,
) -> PipelineProjection {
    let eligible = checked_u64(total_sessions as f64 * eligible_fraction(&spec.input));
    let minutes = spec.resources.minutes_mean * overrun;
    let core_hours = eligible as f64 * minutes / 60.0 * spec.resources.cores as f64;
    // unit economics: HPC charges per core; cloud jobs need enough
    // t2.xlarge instances (4 vCPU each) to cover the core request
    let dollars_hpc =
        eligible as f64 * compute_cost(Env::Hpc, minutes) * spec.resources.cores as f64;
    let instances = ((spec.resources.cores + 3) / 4) as f64;
    let dollars_cloud = eligible as f64 * compute_cost(Env::Cloud, minutes) * instances;
    PipelineProjection {
        pipeline: spec.name,
        eligible_sessions: eligible,
        core_hours,
        dollars_hpc,
        dollars_cloud,
    }
}

/// Project the full 20-dataset × 16-pipeline campaign.
pub fn project_campaign(faults: Option<FaultModel>, max_retries: u32) -> CampaignProjection {
    let total_sessions: u64 = catalog().iter().map(|e: &DatasetCatalogEntry| e.sessions).sum();
    let overrun = faults
        .map(|m| expected_overrun(&m, max_retries, 50_000, 4242))
        .unwrap_or(1.0);
    let per_pipeline = registry()
        .iter()
        .map(|spec| project_pipeline(spec, total_sessions, overrun))
        .collect();
    CampaignProjection {
        per_pipeline,
        overrun_factor: overrun,
    }
}

impl CampaignProjection {
    pub fn total_core_hours(&self) -> f64 {
        self.per_pipeline.iter().map(|p| p.core_hours).sum()
    }

    pub fn total_dollars(&self, env: Env) -> f64 {
        self.per_pipeline
            .iter()
            .map(|p| match env {
                Env::Cloud => p.dollars_cloud,
                _ => p.dollars_hpc,
            })
            .sum()
    }

    pub fn format(&self) -> String {
        let mut s = String::from(
            "Paper-scale campaign projection (52,311 sessions × 16 pipelines)\n",
        );
        s.push_str(&format!(
            "{:<22}{:>10}{:>14}{:>12}{:>12}\n",
            "pipeline", "sessions", "core-hours", "$ HPC", "$ cloud"
        ));
        for p in &self.per_pipeline {
            s.push_str(&format!(
                "{:<22}{:>10}{:>14.0}{:>12.0}{:>12.0}\n",
                p.pipeline, p.eligible_sessions, p.core_hours, p.dollars_hpc, p.dollars_cloud
            ));
        }
        s.push_str(&format!(
            "{:<22}{:>10}{:>14.0}{:>12.0}{:>12.0}\n",
            "TOTAL",
            "",
            self.total_core_hours(),
            self.total_dollars(Env::Hpc),
            self.total_dollars(Env::Cloud)
        ));
        s.push_str(&format!("(fault overrun factor: {:.3}x)\n", self.overrun_factor));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn projection_covers_all_pipelines() {
        let p = project_campaign(None, 0);
        assert_eq!(p.per_pipeline.len(), 16);
        assert_eq!(p.overrun_factor, 1.0);
        assert!(p.total_core_hours() > 0.0);
    }

    #[test]
    fn cloud_many_times_more_expensive_at_scale() {
        let p = project_campaign(None, 0);
        let ratio = p.total_dollars(Env::Cloud) / p.total_dollars(Env::Hpc);
        // per-core pricing gap is ~19x; 4-vCPU instance granularity keeps
        // the effective gap close to that
        assert!(ratio > 4.0, "ratio={ratio}");
        assert!(ratio < 25.0, "ratio={ratio}");
    }

    #[test]
    fn faults_inflate_projection() {
        let clean = project_campaign(None, 3);
        let faulty = project_campaign(Some(FaultModel::typical()), 3);
        assert!(faulty.overrun_factor > 1.0);
        assert!(faulty.total_dollars(Env::Hpc) > clean.total_dollars(Env::Hpc));
        let ratio = faulty.total_core_hours() / clean.total_core_hours();
        assert!((ratio - faulty.overrun_factor).abs() < 1e-6);
    }

    #[test]
    fn eligible_sessions_bounded_by_catalog() {
        let total: u64 = catalog().iter().map(|e| e.sessions).sum();
        for p in project_campaign(None, 0).per_pipeline {
            assert!(p.eligible_sessions <= total);
            assert!(p.eligible_sessions > 0);
        }
    }

    #[test]
    fn format_lists_everything() {
        let text = project_campaign(None, 0).format();
        assert!(text.contains("freesurfer"));
        assert!(text.contains("TOTAL"));
    }
}
