//! Capability model of data-archival solutions — regenerates **Table 3**.
//!
//! The paper compares archival options on three criteria: whether
//! credentials are required to use the archive, whether archival creates
//! potential data-use conflicts, and whether the organizational structure
//! is flexible. The CLI approach (the paper's choice) and Datalad are the
//! only ones with structural flexibility.

/// One archival solution's capability row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArchivalSolution {
    pub name: &'static str,
    pub requires_credentials: bool,
    pub data_use_conflicts: bool,
    pub flexible_structure: bool,
}

/// The eight solutions of Table 3, in paper order.
pub fn solutions() -> Vec<ArchivalSolution> {
    vec![
        ArchivalSolution {
            name: "XNAT",
            requires_credentials: false,
            data_use_conflicts: false,
            flexible_structure: false,
        },
        ArchivalSolution {
            name: "COINS",
            requires_credentials: false,
            data_use_conflicts: true,
            flexible_structure: false,
        },
        ArchivalSolution {
            name: "LORIS",
            requires_credentials: false,
            data_use_conflicts: false,
            flexible_structure: false,
        },
        ArchivalSolution {
            name: "NITRC-IR",
            requires_credentials: false,
            data_use_conflicts: true,
            flexible_structure: false,
        },
        ArchivalSolution {
            name: "OpenNeuro",
            requires_credentials: false,
            data_use_conflicts: true,
            flexible_structure: false,
        },
        ArchivalSolution {
            name: "LONI IDA",
            requires_credentials: true,
            data_use_conflicts: true,
            flexible_structure: false,
        },
        ArchivalSolution {
            name: "Datalad",
            requires_credentials: false,
            data_use_conflicts: false,
            flexible_structure: true,
        },
        ArchivalSolution {
            name: "CLI",
            requires_credentials: false,
            data_use_conflicts: false,
            flexible_structure: true,
        },
    ]
}

/// Score a solution against the paper's design criteria (§1): lower is
/// better; the CLI method must win (it's the paper's pick).
pub fn design_criteria_score(s: &ArchivalSolution) -> u32 {
    s.requires_credentials as u32 + s.data_use_conflicts as u32 + (!s.flexible_structure) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_solutions_in_paper_order() {
        let names: Vec<_> = solutions().iter().map(|s| s.name).collect();
        assert_eq!(
            names,
            ["XNAT", "COINS", "LORIS", "NITRC-IR", "OpenNeuro", "LONI IDA", "Datalad", "CLI"]
        );
    }

    #[test]
    fn only_loni_requires_credentials() {
        for s in solutions() {
            assert_eq!(s.requires_credentials, s.name == "LONI IDA", "{}", s.name);
        }
    }

    #[test]
    fn only_datalad_and_cli_flexible() {
        for s in solutions() {
            assert_eq!(
                s.flexible_structure,
                matches!(s.name, "Datalad" | "CLI"),
                "{}",
                s.name
            );
        }
    }

    #[test]
    fn cli_ties_or_beats_all_on_design_criteria() {
        let all = solutions();
        let cli = all.iter().find(|s| s.name == "CLI").unwrap();
        for s in &all {
            assert!(design_criteria_score(cli) <= design_criteria_score(s), "{}", s.name);
        }
    }
}
