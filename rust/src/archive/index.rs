//! Sharded entity index + persistent processed-set index — the curation
//! hot path at catalog scale (paper §2.3; DESIGN.md §6).
//!
//! The seed implementation of [`crate::query::find_runnable`] walks the
//! whole BIDS tree on every campaign: one `read_dir` per subject, session
//! and modality directory. That is fine for MASiVar's six scans and
//! unusable for the Table 4 catalog (~52k sessions) or anything larger.
//! This module holds the two persistent structures that turn repeated
//! curation from O(all sessions) filesystem walks into O(changes):
//!
//! * [`EntityIndex`] — a sharded inverted index over BIDS entities
//!   (subject / session / modality → image paths). Built once from a full
//!   walk, maintained incrementally by the ingest path
//!   ([`crate::workload::ingest_cohort`]) and refreshed cheaply by
//!   [`EntityIndex::refresh`]. Shards are hashed by subject so
//!   [`crate::query`] can scan them in parallel with
//!   [`crate::util::pool::run_parallel`].
//! * [`ProcessedIndex`] — the persistent processed-set: which sessions
//!   each pipeline has already completed, with a per-pipeline version
//!   counter that lets dependent pipelines detect "my prerequisite just
//!   finished something" without re-walking `derivatives/`.
//!
//! Both persist as JSON under `<dataset>/.medflow/` (see
//! [`crate::bids::BidsDataset::index_dir`]) so a fresh control-node
//! process — or a second campaign — sees the same state without a rescan.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::bids::{BidsDataset, BidsName, Modality};
use crate::util::json::{Json, JsonObj};

/// Default shard count: enough to spread a Table 4–scale catalog across a
/// workstation's cores without fragmenting tiny datasets.
pub const DEFAULT_SHARDS: usize = 16;

/// Identity of one scanning session (the query engine's unit of work).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SessionKey {
    pub subject: String,
    /// `None` for subjects without a `ses-*` level (BIDS allows this).
    pub session: Option<String>,
}

impl SessionKey {
    pub fn new(subject: &str, session: Option<&str>) -> Self {
        Self {
            subject: subject.to_string(),
            session: session.map(str::to_string),
        }
    }

    /// Human-readable label `sub-X[/ses-Y]` (stable across runs).
    pub fn label(&self) -> String {
        match &self.session {
            Some(ses) => format!("sub-{}/ses-{}", self.subject, ses),
            None => format!("sub-{}", self.subject),
        }
    }

    /// Serialize to the canonical `{subject, session?}` JSON shape shared
    /// by every `.medflow/` file that embeds session keys.
    pub(crate) fn to_json(&self) -> JsonObj {
        let mut o = JsonObj::new();
        o.set("subject", Json::str(&self.subject));
        if let Some(ses) = &self.session {
            o.set("session", Json::str(ses));
        }
        o
    }

    /// Inverse of [`Self::to_json`]; extra keys are ignored.
    pub(crate) fn from_json(j: &Json) -> Option<Self> {
        Some(Self {
            subject: j.get_path("subject")?.as_str()?.to_string(),
            session: j.get_path("session").and_then(Json::as_str).map(String::from),
        })
    }
}

/// What the index knows about one session: the image paths per modality
/// (stored **relative to the dataset root**, so the persisted index
/// survives the dataset moving or being opened from a different working
/// directory) plus a generation stamp used to invalidate cached query
/// verdicts when the session's contents change.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SessionRecord {
    pub t1w: Vec<PathBuf>,
    pub dwi: Vec<PathBuf>,
    /// Index generation at which this record was last (re)written.
    pub generation: u64,
}

impl SessionRecord {
    /// Dataset-root-relative image paths of one modality.
    pub fn images(&self, modality: Modality) -> &[PathBuf] {
        match modality {
            Modality::T1w => &self.t1w,
            Modality::Dwi => &self.dwi,
        }
    }

    /// Image paths of one modality resolved against the dataset root —
    /// what query evaluation and [`crate::query::JobSpec`] inputs use.
    pub fn resolved(&self, ds: &BidsDataset, modality: Modality) -> Vec<PathBuf> {
        self.images(modality).iter().map(|p| ds.root.join(p)).collect()
    }
}

/// FNV-1a — stable across processes (unlike `DefaultHasher`), so shard
/// assignment survives save/load.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The sharded inverted index over BIDS entities.
///
/// All sessions of one subject land in the same shard (subject-hashed), so
/// a parallel scan never races on a subject and per-shard output stays
/// deterministic.
#[derive(Debug, Clone)]
pub struct EntityIndex {
    shards: Vec<BTreeMap<SessionKey, SessionRecord>>,
    /// Bumped on every mutation; recorded into each touched
    /// [`SessionRecord::generation`].
    pub generation: u64,
    /// Shards mutated since the last save/load (not persisted) — saves
    /// rewrite only these, keeping persistence O(changes) too.
    dirty: BTreeSet<usize>,
}

impl EntityIndex {
    /// An empty index with `n_shards` shards (at least 1).
    pub fn new(n_shards: usize) -> Self {
        Self {
            shards: (0..n_shards.max(1)).map(|_| BTreeMap::new()).collect(),
            generation: 0,
            dirty: BTreeSet::new(),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total indexed sessions.
    pub fn len(&self) -> usize {
        self.shards.iter().map(BTreeMap::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(BTreeMap::is_empty)
    }

    /// Shard index a subject's sessions live in.
    pub fn shard_of(&self, subject: &str) -> usize {
        (fnv1a(subject.as_bytes()) % self.shards.len() as u64) as usize
    }

    /// One shard's sessions (sorted by key).
    pub fn shard(&self, i: usize) -> &BTreeMap<SessionKey, SessionRecord> {
        &self.shards[i]
    }

    /// Look up one session.
    pub fn get(&self, key: &SessionKey) -> Option<&SessionRecord> {
        self.shards[self.shard_of(&key.subject)].get(key)
    }

    /// Whether a session is indexed.
    pub fn contains(&self, key: &SessionKey) -> bool {
        self.get(key).is_some()
    }

    /// (Re)index one session from the filesystem: two `read_dir`s, O(1) in
    /// dataset size. This is the maintenance hook the ingest path calls per
    /// newly acquired session. Paths are stored relative to the dataset
    /// root so the persisted index is relocation-safe.
    pub fn record_session(&mut self, ds: &BidsDataset, key: &SessionKey) {
        let ses = key.session.as_deref();
        let relativize = |paths: Vec<PathBuf>| -> Vec<PathBuf> {
            paths
                .into_iter()
                .map(|p| p.strip_prefix(&ds.root).map(PathBuf::from).unwrap_or(p))
                .collect()
        };
        let t1w = relativize(ds.raw_images(&BidsName::new(&key.subject, ses, Modality::T1w)));
        let dwi = relativize(ds.raw_images(&BidsName::new(&key.subject, ses, Modality::Dwi)));
        self.generation += 1;
        let rec = SessionRecord {
            t1w,
            dwi,
            generation: self.generation,
        };
        let shard = self.shard_of(&key.subject);
        self.shards[shard].insert(key.clone(), rec);
        self.dirty.insert(shard);
    }

    /// Build from a full walk of the dataset — the one-time cost the index
    /// amortizes away. Every session directory is indexed, including
    /// sessions with zero curatable images (those still feed the skip CSV).
    /// All shards are marked dirty — a built index must fully overwrite
    /// whatever save files precede it (a rebuild may have emptied a shard).
    pub fn build(ds: &BidsDataset, n_shards: usize) -> Result<Self> {
        let mut index = Self::new(n_shards);
        for subject in ds.subjects()? {
            for session in ds.sessions(&subject)? {
                let key = SessionKey::new(&subject, session.as_deref());
                index.record_session(ds, &key);
            }
        }
        index.dirty = (0..index.shards.len()).collect();
        Ok(index)
    }

    /// Incremental discovery of newly acquired sessions: enumerates the
    /// subject/session directory level only (no per-modality or per-file
    /// walks) and indexes keys not yet present. Returns the keys added.
    ///
    /// Contract: a writer that *adds images to an existing session* must
    /// call [`Self::record_session`] itself (as the ingest path does);
    /// `refresh` only discovers whole new sessions.
    pub fn refresh(&mut self, ds: &BidsDataset) -> Result<Vec<SessionKey>> {
        let mut added = Vec::new();
        for subject in ds.subjects()? {
            for session in ds.sessions(&subject)? {
                let key = SessionKey::new(&subject, session.as_deref());
                if !self.contains(&key) {
                    self.record_session(ds, &key);
                    added.push(key);
                }
            }
        }
        Ok(added)
    }

    /// Persist: one JSON file per shard plus `meta.json`, under `dir`.
    /// Only shards mutated since the last save/load (plus any whose file
    /// is missing on disk) are rewritten.
    pub fn save(&mut self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut meta = JsonObj::new();
        meta.set("n_shards", Json::num(self.shards.len() as f64));
        meta.set("generation", Json::num(self.generation as f64));
        std::fs::write(dir.join("meta.json"), Json::Obj(meta).to_string_pretty())?;
        for (i, shard) in self.shards.iter().enumerate() {
            let path = dir.join(format!("shard-{i:03}.json"));
            if !self.dirty.contains(&i) && path.exists() {
                continue;
            }
            let sessions: Vec<Json> = shard
                .iter()
                .map(|(key, rec)| {
                    let mut o = key.to_json();
                    o.set("generation", Json::num(rec.generation as f64));
                    o.set(
                        "t1w",
                        Json::Arr(rec.t1w.iter().map(|p| Json::str(p.to_string_lossy())).collect()),
                    );
                    o.set(
                        "dwi",
                        Json::Arr(rec.dwi.iter().map(|p| Json::str(p.to_string_lossy())).collect()),
                    );
                    Json::Obj(o)
                })
                .collect();
            let mut o = JsonObj::new();
            o.set("sessions", Json::Arr(sessions));
            std::fs::write(&path, Json::Obj(o).to_string_pretty())?;
        }
        self.dirty.clear();
        Ok(())
    }

    /// Load a previously saved index.
    pub fn load(dir: &Path) -> Result<Self> {
        let meta_path = dir.join("meta.json");
        let meta = Json::parse(
            &std::fs::read_to_string(&meta_path).with_context(|| format!("read {meta_path:?}"))?,
        )?;
        let n_shards = meta
            .get_path("n_shards")
            .and_then(Json::as_i64)
            .context("index meta missing n_shards")? as usize;
        let mut index = Self::new(n_shards);
        index.generation = meta
            .get_path("generation")
            .and_then(Json::as_i64)
            .unwrap_or(0) as u64;
        for i in 0..n_shards {
            let path = dir.join(format!("shard-{i:03}.json"));
            let json = Json::parse(
                &std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?,
            )?;
            for s in json.get_path("sessions").and_then(Json::as_arr).unwrap_or(&[]) {
                let Some(key) = SessionKey::from_json(s) else {
                    continue;
                };
                let paths = |field: &str| -> Vec<PathBuf> {
                    s.get_path(field)
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(Json::as_str)
                        .map(PathBuf::from)
                        .collect()
                };
                let rec = SessionRecord {
                    t1w: paths("t1w"),
                    dwi: paths("dwi"),
                    generation: s.get_path("generation").and_then(Json::as_i64).unwrap_or(0) as u64,
                };
                index.shards[i].insert(key, rec);
            }
        }
        Ok(index)
    }

    /// Load the dataset's persisted index, or build (full walk) and persist
    /// one if none exists yet.
    pub fn open_or_build(ds: &BidsDataset, n_shards: usize) -> Result<Self> {
        let dir = ds.index_dir().join("index");
        if dir.join("meta.json").exists() {
            Self::load(&dir)
        } else {
            let mut index = Self::build(ds, n_shards)?;
            index.save(&dir)?;
            Ok(index)
        }
    }

    /// Persist to the dataset's conventional index location.
    pub fn save_for(&mut self, ds: &BidsDataset) -> Result<()> {
        self.save(&ds.index_dir().join("index"))
    }
}

/// The persistent processed-set: `pipeline → {completed sessions}` plus a
/// per-pipeline version counter (bumped whenever the set grows) that
/// dependent pipelines use to detect unblocking cheaply.
#[derive(Debug, Clone, Default)]
pub struct ProcessedIndex {
    done: BTreeMap<String, BTreeSet<SessionKey>>,
    versions: BTreeMap<String, u64>,
}

impl ProcessedIndex {
    /// Record a completion. Returns `true` if the session was newly added
    /// (the pipeline's version is bumped only then).
    pub fn mark(&mut self, pipeline: &str, key: SessionKey) -> bool {
        let fresh = self.done.entry(pipeline.to_string()).or_default().insert(key);
        if fresh {
            *self.versions.entry(pipeline.to_string()).or_insert(0) += 1;
        }
        fresh
    }

    /// Whether `pipeline` has completed `key`.
    pub fn contains(&self, pipeline: &str, key: &SessionKey) -> bool {
        self.done.get(pipeline).is_some_and(|s| s.contains(key))
    }

    /// Forget a pipeline's processed set while **bumping** its version —
    /// the out-of-band invalidation hook: dependents' cached
    /// `MissingPrior` verdicts are version-stamped, so the bump forces
    /// them to re-examine; the sessions themselves fall back to a
    /// `derivatives/` probe and re-absorb whatever still exists.
    pub fn reset(&mut self, pipeline: &str) {
        self.done.remove(pipeline);
        *self.versions.entry(pipeline.to_string()).or_insert(0) += 1;
    }

    /// Monotonic version of a pipeline's processed set (0 = never ran).
    pub fn version(&self, pipeline: &str) -> u64 {
        self.versions.get(pipeline).copied().unwrap_or(0)
    }

    /// Completed-session count for a pipeline.
    pub fn count(&self, pipeline: &str) -> usize {
        self.done.get(pipeline).map_or(0, BTreeSet::len)
    }

    /// Completed sessions of a pipeline, in key order.
    pub fn keys(&self, pipeline: &str) -> impl Iterator<Item = &SessionKey> {
        self.done.get(pipeline).into_iter().flatten()
    }

    /// Persist as a single JSON document. Iterates the union of the
    /// processed sets and the version map: a pipeline whose set was
    /// emptied by [`Self::reset`] must still persist its bumped version,
    /// or cross-process invalidation would be silently lost.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let names: BTreeSet<&String> = self.done.keys().chain(self.versions.keys()).collect();
        let mut pipelines = Vec::new();
        for pipeline in names {
            let mut o = JsonObj::new();
            o.set("pipeline", Json::str(pipeline.as_str()));
            o.set("version", Json::num(self.version(pipeline) as f64));
            o.set(
                "sessions",
                Json::Arr(
                    self.done
                        .get(pipeline.as_str())
                        .into_iter()
                        .flatten()
                        .map(|k| Json::Obj(k.to_json()))
                        .collect(),
                ),
            );
            pipelines.push(Json::Obj(o));
        }
        let mut root = JsonObj::new();
        root.set("pipelines", Json::Arr(pipelines));
        std::fs::write(path, Json::Obj(root).to_string_pretty())?;
        Ok(())
    }

    /// Load from disk; a missing file is an empty index (nothing processed).
    pub fn load(path: &Path) -> Result<Self> {
        if !path.exists() {
            return Ok(Self::default());
        }
        let json = Json::parse(
            &std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?,
        )?;
        let mut out = Self::default();
        for p in json.get_path("pipelines").and_then(Json::as_arr).unwrap_or(&[]) {
            let Some(name) = p.get_path("pipeline").and_then(Json::as_str) else {
                continue;
            };
            let keys: BTreeSet<SessionKey> = p
                .get_path("sessions")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(SessionKey::from_json)
                .collect();
            let version = p.get_path("version").and_then(Json::as_i64).unwrap_or(0) as u64;
            out.versions.insert(name.to_string(), version.max(keys.len() as u64));
            out.done.insert(name.to_string(), keys);
        }
        Ok(out)
    }

    /// Conventional on-disk location for a dataset.
    pub fn path_for(ds: &BidsDataset) -> PathBuf {
        ds.index_dir().join("processed.json")
    }

    /// Load the dataset's processed index (empty if never saved).
    pub fn open(ds: &BidsDataset) -> Result<Self> {
        Self::load(&Self::path_for(ds))
    }

    /// Persist to the dataset's conventional location.
    pub fn save_for(&self, ds: &BidsDataset) -> Result<()> {
        self.save(&Self::path_for(ds))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpds(tag: &str) -> BidsDataset {
        let parent = std::env::temp_dir().join(format!("medflow_idx_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&parent).unwrap();
        BidsDataset::create(&parent, "DS").unwrap()
    }

    fn add_image(ds: &BidsDataset, sub: &str, ses: Option<&str>, m: Modality) {
        let name = BidsName::new(sub, ses, m);
        let p = ds.raw_path(&name, "nii.gz");
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(&p, b"img").unwrap();
    }

    fn cleanup(ds: &BidsDataset) {
        std::fs::remove_dir_all(ds.root.parent().unwrap()).unwrap();
    }

    #[test]
    fn build_indexes_every_session_including_empty() {
        let ds = tmpds("build");
        add_image(&ds, "01", Some("a"), Modality::T1w);
        add_image(&ds, "01", Some("b"), Modality::Dwi);
        add_image(&ds, "02", None, Modality::T1w);
        // session with no curatable images at all
        let name = BidsName::new("03", Some("x"), Modality::T1w);
        std::fs::create_dir_all(ds.raw_dir(&name).parent().unwrap()).unwrap();
        let idx = EntityIndex::build(&ds, 4).unwrap();
        assert_eq!(idx.len(), 4);
        let rec = idx.get(&SessionKey::new("01", Some("a"))).unwrap();
        assert_eq!(rec.t1w.len(), 1);
        assert!(rec.dwi.is_empty());
        let empty = idx.get(&SessionKey::new("03", Some("x"))).unwrap();
        assert!(empty.t1w.is_empty() && empty.dwi.is_empty());
        cleanup(&ds);
    }

    #[test]
    fn save_load_roundtrip_preserves_shards() {
        let ds = tmpds("roundtrip");
        for i in 0..10 {
            add_image(&ds, &format!("{i:02}"), Some("a"), Modality::T1w);
        }
        let mut idx = EntityIndex::build(&ds, 4).unwrap();
        idx.save_for(&ds).unwrap();
        let again = EntityIndex::load(&ds.index_dir().join("index")).unwrap();
        assert_eq!(again.len(), idx.len());
        assert_eq!(again.n_shards(), 4);
        assert_eq!(again.generation, idx.generation);
        for i in 0..4 {
            assert_eq!(again.shard(i), idx.shard(i), "shard {i}");
        }
        cleanup(&ds);
    }

    #[test]
    fn shard_assignment_stable_and_subject_local() {
        let idx = EntityIndex::new(8);
        let s1 = idx.shard_of("0042");
        assert_eq!(s1, idx.shard_of("0042"), "hash must be deterministic");
        // all sessions of a subject land in one shard by construction
        let idx2 = EntityIndex::new(8);
        assert_eq!(s1, idx2.shard_of("0042"), "stable across instances");
    }

    #[test]
    fn refresh_discovers_only_new_sessions() {
        let ds = tmpds("refresh");
        add_image(&ds, "01", Some("a"), Modality::T1w);
        let mut idx = EntityIndex::build(&ds, 4).unwrap();
        assert!(idx.refresh(&ds).unwrap().is_empty());
        add_image(&ds, "01", Some("b"), Modality::Dwi);
        add_image(&ds, "02", None, Modality::T1w);
        let added = idx.refresh(&ds).unwrap();
        assert_eq!(added.len(), 2);
        assert!(idx.contains(&SessionKey::new("01", Some("b"))));
        assert!(idx.contains(&SessionKey::new("02", None)));
        cleanup(&ds);
    }

    #[test]
    fn record_session_bumps_generation() {
        let ds = tmpds("gen");
        add_image(&ds, "01", Some("a"), Modality::T1w);
        let mut idx = EntityIndex::build(&ds, 2).unwrap();
        let key = SessionKey::new("01", Some("a"));
        let g0 = idx.get(&key).unwrap().generation;
        add_image(&ds, "01", Some("a"), Modality::Dwi);
        idx.record_session(&ds, &key);
        let rec = idx.get(&key).unwrap();
        assert!(rec.generation > g0);
        assert_eq!(rec.dwi.len(), 1);
        cleanup(&ds);
    }

    #[test]
    fn processed_index_marks_versions_and_persists() {
        let ds = tmpds("proc");
        let mut p = ProcessedIndex::default();
        assert_eq!(p.version("freesurfer"), 0);
        let k = SessionKey::new("01", Some("a"));
        assert!(p.mark("freesurfer", k.clone()));
        assert!(!p.mark("freesurfer", k.clone()), "re-mark is a no-op");
        assert_eq!(p.version("freesurfer"), 1);
        assert!(p.contains("freesurfer", &k));
        assert_eq!(p.count("freesurfer"), 1);
        p.mark("freesurfer", SessionKey::new("02", None));
        assert_eq!(p.version("freesurfer"), 2);
        p.save_for(&ds).unwrap();
        let again = ProcessedIndex::open(&ds).unwrap();
        assert!(again.contains("freesurfer", &k));
        assert_eq!(again.version("freesurfer"), 2);
        assert_eq!(again.keys("freesurfer").count(), 2);
        cleanup(&ds);
    }

    #[test]
    fn reset_version_bump_survives_save_load() {
        let ds = tmpds("resetver");
        let mut p = ProcessedIndex::default();
        p.mark("prequal", SessionKey::new("01", None));
        p.reset("prequal");
        assert_eq!(p.version("prequal"), 2);
        assert_eq!(p.count("prequal"), 0);
        // an empty processed set must still persist its bumped version —
        // cross-process invalidation depends on it
        p.save_for(&ds).unwrap();
        let again = ProcessedIndex::open(&ds).unwrap();
        assert_eq!(again.version("prequal"), 2);
        assert_eq!(again.count("prequal"), 0);
        assert!(!again.contains("prequal", &SessionKey::new("01", None)));
        cleanup(&ds);
    }

    #[test]
    fn open_or_build_persists_first_build() {
        let ds = tmpds("openbuild");
        add_image(&ds, "01", None, Modality::T1w);
        let first = EntityIndex::open_or_build(&ds, 4).unwrap();
        assert_eq!(first.len(), 1);
        // second open loads the persisted copy (no rebuild needed even if
        // the tree grows — refresh is the explicit delta hook)
        add_image(&ds, "02", None, Modality::T1w);
        let second = EntityIndex::open_or_build(&ds, 4).unwrap();
        assert_eq!(second.len(), 1, "load, not rebuild");
        cleanup(&ds);
    }

    #[test]
    fn missing_processed_file_is_empty() {
        let ds = tmpds("noproc");
        let p = ProcessedIndex::open(&ds).unwrap();
        assert_eq!(p.count("freesurfer"), 0);
        cleanup(&ds);
    }
}
