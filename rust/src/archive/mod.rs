//! Data archive: the paper's dual near-line storage system (§2.2, Fig. 3).
//!
//! Two RAID-Z2 servers — a 407 TB general store and a 266 TB GDPR-compliant
//! store — hold the raw + processed data; BIDS trees contain only symlinks
//! into the store (handled by [`crate::bids`]). The archive tracks which
//! dataset lives on which server, enforces tier placement, and reports the
//! usage statistics the resource monitor queries (§2.3).

pub mod growth;
pub mod index;
pub mod solutions;

pub use index::{EntityIndex, ProcessedIndex, SessionKey, SessionRecord, DEFAULT_SHARDS};

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::units::TB;

/// Security tier of a dataset (the paper splits UKBB-style GDPR data from
/// the rest).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SecurityTier {
    General,
    Gdpr,
}

/// Disk media class — matters for the transfer model (paper §4: the
/// storage servers are HDD, local/AWS instances are SSD).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DiskMedia {
    Hdd,
    Ssd,
}

/// One storage server.
#[derive(Debug, Clone)]
pub struct StorageServer {
    pub name: String,
    pub root: PathBuf,
    pub capacity_bytes: u64,
    pub tier: SecurityTier,
    pub media: DiskMedia,
}

impl StorageServer {
    /// The paper's general-purpose server: 407 TB RAID-Z2, HDD.
    pub fn general(root: PathBuf) -> Self {
        Self {
            name: "general-407tb".into(),
            root,
            capacity_bytes: 407 * TB,
            tier: SecurityTier::General,
            media: DiskMedia::Hdd,
        }
    }

    /// The paper's GDPR server: 266 TB RAID-Z2, HDD.
    pub fn gdpr(root: PathBuf) -> Self {
        Self {
            name: "gdpr-266tb".into(),
            root,
            capacity_bytes: 266 * TB,
            tier: SecurityTier::Gdpr,
            media: DiskMedia::Hdd,
        }
    }
}

/// Usage statistics for one dataset in the store.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DatasetUsage {
    pub bytes: u64,
    pub file_count: u64,
    pub raw_image_count: u64,
}

/// The archive: servers + dataset registry + on-disk layout
/// `<server_root>/<dataset>/raw/...` and `<server_root>/<dataset>/proc/...`.
#[derive(Debug)]
pub struct Archive {
    pub general: StorageServer,
    pub gdpr: StorageServer,
    datasets: BTreeMap<String, SecurityTier>,
}

impl Archive {
    pub fn new(general: StorageServer, gdpr: StorageServer) -> Result<Self> {
        std::fs::create_dir_all(&general.root)?;
        std::fs::create_dir_all(&gdpr.root)?;
        // re-discover datasets already on disk (the registry is the
        // directory layout itself — a fresh control-node process sees the
        // same archive state, paper Fig. 3)
        let mut datasets = BTreeMap::new();
        for (server, tier) in [(&general, SecurityTier::General), (&gdpr, SecurityTier::Gdpr)] {
            for entry in std::fs::read_dir(&server.root)?.flatten() {
                if entry.file_type().map(|t| t.is_dir()).unwrap_or(false)
                    && entry.path().join("raw").is_dir()
                {
                    datasets.insert(entry.file_name().to_string_lossy().to_string(), tier);
                }
            }
        }
        Ok(Self {
            general,
            gdpr,
            datasets,
        })
    }

    /// Convenience: both servers under one temp root (tests/examples).
    pub fn at(root: &Path) -> Result<Self> {
        Self::new(
            StorageServer::general(root.join("general")),
            StorageServer::gdpr(root.join("gdpr")),
        )
    }

    /// Register a dataset on the tier its compliance requires. The GDPR
    /// server only holds GDPR datasets, and vice versa (paper Fig. 3).
    pub fn register_dataset(&mut self, name: &str, tier: SecurityTier) -> Result<()> {
        if self.datasets.contains_key(name) {
            bail!("dataset '{name}' already registered");
        }
        self.datasets.insert(name.to_string(), tier);
        std::fs::create_dir_all(self.dataset_root(name)?.join("raw"))?;
        std::fs::create_dir_all(self.dataset_root(name)?.join("proc"))?;
        Ok(())
    }

    pub fn tier_of(&self, dataset: &str) -> Option<SecurityTier> {
        self.datasets.get(dataset).copied()
    }

    pub fn datasets(&self) -> impl Iterator<Item = (&str, SecurityTier)> {
        self.datasets.iter().map(|(k, v)| (k.as_str(), *v))
    }

    fn server_for(&self, tier: SecurityTier) -> &StorageServer {
        match tier {
            SecurityTier::General => &self.general,
            SecurityTier::Gdpr => &self.gdpr,
        }
    }

    /// Root directory of a dataset's store area.
    pub fn dataset_root(&self, dataset: &str) -> Result<PathBuf> {
        let tier = self
            .tier_of(dataset)
            .with_context(|| format!("dataset '{dataset}' not registered"))?;
        Ok(self.server_for(tier).root.join(dataset))
    }

    /// Store a raw data file; returns its store path (the symlink target
    /// for the BIDS tree).
    pub fn store_raw(&self, dataset: &str, rel: &str, bytes: &[u8]) -> Result<PathBuf> {
        let path = self.dataset_root(dataset)?.join("raw").join(rel);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&path, bytes)?;
        Ok(path)
    }

    /// Directory where a pipeline's outputs for a dataset live in the store.
    pub fn proc_dir(&self, dataset: &str, pipeline: &str) -> Result<PathBuf> {
        Ok(self.dataset_root(dataset)?.join("proc").join(pipeline))
    }

    /// Walk a dataset's store area and count bytes/files (the Table 4
    /// inventory columns and the §2.3 resource monitor's storage view).
    pub fn usage(&self, dataset: &str) -> Result<DatasetUsage> {
        let root = self.dataset_root(dataset)?;
        let mut usage = DatasetUsage::default();
        let mut stack = vec![root];
        while let Some(dir) = stack.pop() {
            for entry in std::fs::read_dir(&dir).into_iter().flatten().flatten() {
                let path = entry.path();
                if path.is_dir() {
                    stack.push(path);
                } else {
                    usage.file_count += 1;
                    usage.bytes += entry.metadata().map(|m| m.len()).unwrap_or(0);
                    let s = path.to_string_lossy();
                    if s.ends_with(".nii") || s.ends_with(".nii.gz") {
                        usage.raw_image_count += 1;
                    }
                }
            }
        }
        Ok(usage)
    }

    /// Total bytes across all datasets on one tier (capacity monitoring).
    pub fn tier_usage(&self, tier: SecurityTier) -> Result<u64> {
        let mut total = 0;
        for (name, t) in self.datasets.clone() {
            if t == tier {
                total += self.usage(&name)?.bytes;
            }
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("medflow_arch_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn datasets_placed_on_their_tier() {
        let root = tmp("tier");
        let mut a = Archive::at(&root).unwrap();
        a.register_dataset("ADNI", SecurityTier::General).unwrap();
        a.register_dataset("UKBB", SecurityTier::Gdpr).unwrap();
        assert!(a.dataset_root("ADNI").unwrap().starts_with(root.join("general")));
        assert!(a.dataset_root("UKBB").unwrap().starts_with(root.join("gdpr")));
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn duplicate_registration_rejected() {
        let root = tmp("dup");
        let mut a = Archive::at(&root).unwrap();
        a.register_dataset("ADNI", SecurityTier::General).unwrap();
        assert!(a.register_dataset("ADNI", SecurityTier::General).is_err());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn unregistered_dataset_errors() {
        let root = tmp("unreg");
        let a = Archive::at(&root).unwrap();
        assert!(a.dataset_root("NOPE").is_err());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn usage_counts_bytes_files_images() {
        let root = tmp("usage");
        let mut a = Archive::at(&root).unwrap();
        a.register_dataset("DS", SecurityTier::General).unwrap();
        a.store_raw("DS", "sub-01/x.nii.gz", &[0u8; 100]).unwrap();
        a.store_raw("DS", "sub-01/x.json", &[0u8; 10]).unwrap();
        a.store_raw("DS", "sub-02/y.nii", &[0u8; 50]).unwrap();
        let u = a.usage("DS").unwrap();
        assert_eq!(u.file_count, 3);
        assert_eq!(u.raw_image_count, 2);
        assert_eq!(u.bytes, 160);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn tier_usage_separates_servers() {
        let root = tmp("tieruse");
        let mut a = Archive::at(&root).unwrap();
        a.register_dataset("A", SecurityTier::General).unwrap();
        a.register_dataset("B", SecurityTier::Gdpr).unwrap();
        a.store_raw("A", "f", &[0u8; 30]).unwrap();
        a.store_raw("B", "f", &[0u8; 70]).unwrap();
        assert_eq!(a.tier_usage(SecurityTier::General).unwrap(), 30);
        assert_eq!(a.tier_usage(SecurityTier::Gdpr).unwrap(), 70);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn registry_rediscovered_on_reopen() {
        let root = tmp("reopen");
        {
            let mut a = Archive::at(&root).unwrap();
            a.register_dataset("ADNI", SecurityTier::General).unwrap();
            a.register_dataset("UKBB", SecurityTier::Gdpr).unwrap();
            a.store_raw("ADNI", "x", &[1u8; 4]).unwrap();
        }
        // a fresh process sees the same archive state
        let a = Archive::at(&root).unwrap();
        assert_eq!(a.tier_of("ADNI"), Some(SecurityTier::General));
        assert_eq!(a.tier_of("UKBB"), Some(SecurityTier::Gdpr));
        assert_eq!(a.usage("ADNI").unwrap().bytes, 4);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn server_constants_match_paper() {
        let g = StorageServer::general(PathBuf::from("/tmp/x"));
        assert_eq!(g.capacity_bytes, 407 * TB);
        assert_eq!(g.media, DiskMedia::Hdd);
        let s = StorageServer::gdpr(PathBuf::from("/tmp/y"));
        assert_eq!(s.capacity_bytes, 266 * TB);
    }
}
