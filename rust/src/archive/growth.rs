//! Storage growth forecasting (paper §2.1: ADNI/NACC keep scanning; new
//! data is pulled every 6–12 months — capacity on the 407 TB + 266 TB
//! servers must be planned, and the Glacier bill forecast).

use crate::cost::{accre_storage_cost_per_year, glacier_cost_per_month};
use crate::util::units::TB;
use crate::workload::catalog;

/// One dataset's growth model: current bytes + bytes added per pull.
#[derive(Debug, Clone)]
pub struct GrowthModel {
    pub dataset: String,
    pub current_bytes: u64,
    pub bytes_per_pull: u64,
    /// Pulls per year (paper: 1–2).
    pub pulls_per_year: f64,
}

impl GrowthModel {
    /// Size after `years`.
    pub fn at_years(&self, years: f64) -> u64 {
        self.current_bytes
            + (self.bytes_per_pull as f64 * self.pulls_per_year * years).round() as u64
    }
}

/// Default growth models from the Table 4 catalog: the actively-scanning
/// studies (ADNI, NACC, UKBB, HABS-HD, per the paper) grow ~8%/pull at 2
/// pulls/year; completed studies are static.
pub fn default_models() -> Vec<GrowthModel> {
    const ACTIVE: [&str; 4] = ["ADNI", "NACC", "UKBB", "HABS-HD"];
    catalog()
        .iter()
        .map(|e| {
            let bytes = (e.size_tb * TB as f64) as u64;
            let active = ACTIVE.contains(&e.name);
            GrowthModel {
                dataset: e.name.to_string(),
                current_bytes: bytes,
                bytes_per_pull: if active { bytes / 12 } else { 0 },
                pulls_per_year: if active { 2.0 } else { 0.0 },
            }
        })
        .collect()
}

/// Forecast of total archive demand vs server capacity.
#[derive(Debug, Clone)]
pub struct CapacityForecast {
    pub years: f64,
    pub general_bytes: u64,
    pub gdpr_bytes: u64,
    pub general_capacity: u64,
    pub gdpr_capacity: u64,
    pub glacier_dollars_per_month: f64,
    pub accre_equiv_dollars_per_year: f64,
}

impl CapacityForecast {
    pub fn general_headroom(&self) -> f64 {
        1.0 - self.general_bytes as f64 / self.general_capacity as f64
    }

    pub fn gdpr_headroom(&self) -> f64 {
        1.0 - self.gdpr_bytes as f64 / self.gdpr_capacity as f64
    }

    pub fn any_exhausted(&self) -> bool {
        self.general_headroom() < 0.0 || self.gdpr_headroom() < 0.0
    }
}

/// Forecast at `years` from now with the given models (UKBB is the GDPR
/// tenant; everything else shares the general server — paper Fig. 3).
pub fn forecast(models: &[GrowthModel], years: f64) -> CapacityForecast {
    let mut general = 0u64;
    let mut gdpr = 0u64;
    for m in models {
        let size = m.at_years(years);
        if m.dataset == "UKBB" {
            gdpr += size;
        } else {
            general += size;
        }
    }
    let total = general + gdpr;
    CapacityForecast {
        years,
        general_bytes: general,
        gdpr_bytes: gdpr,
        general_capacity: 407 * TB,
        gdpr_capacity: 266 * TB,
        glacier_dollars_per_month: glacier_cost_per_month(total),
        accre_equiv_dollars_per_year: accre_storage_cost_per_year(total),
    }
}

/// Years until either server exhausts (bisection over the linear model).
pub fn years_until_exhaustion(models: &[GrowthModel]) -> Option<f64> {
    if !forecast(models, 100.0).any_exhausted() {
        return None;
    }
    let (mut lo, mut hi) = (0.0f64, 100.0f64);
    for _ in 0..60 {
        let mid = (lo + hi) / 2.0;
        if forecast(models, mid).any_exhausted() {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn current_state_matches_catalog() {
        let f = forecast(&default_models(), 0.0);
        // Table 4: 287.9 TB total; UKBB 79 TB on GDPR
        assert!((f.gdpr_bytes as f64 / TB as f64 - 79.0).abs() < 0.5);
        assert!((f.general_bytes as f64 / TB as f64 - 208.9).abs() < 1.0);
        assert!(!f.any_exhausted());
        assert!(f.general_headroom() > 0.4);
    }

    #[test]
    fn growth_is_monotone() {
        let models = default_models();
        let a = forecast(&models, 1.0);
        let b = forecast(&models, 5.0);
        assert!(b.general_bytes > a.general_bytes);
        assert!(b.gdpr_bytes > a.gdpr_bytes);
        assert!(b.glacier_dollars_per_month > a.glacier_dollars_per_month);
    }

    #[test]
    fn static_studies_do_not_grow() {
        let models = default_models();
        let camcan = models.iter().find(|m| m.dataset == "CAMCAN").unwrap();
        assert_eq!(camcan.at_years(10.0), camcan.current_bytes);
    }

    #[test]
    fn exhaustion_eventually_happens_and_is_bracketed() {
        let models = default_models();
        let years = years_until_exhaustion(&models).expect("active growth must exhaust");
        assert!(years > 1.0, "{years}");
        assert!(!forecast(&models, years - 0.1).any_exhausted());
        assert!(forecast(&models, years + 0.1).any_exhausted());
    }

    #[test]
    fn glacier_remains_cheaper_than_accre_storage() {
        let f = forecast(&default_models(), 3.0);
        assert!(f.glacier_dollars_per_month * 12.0 < f.accre_equiv_dollars_per_year / 2.0);
    }
}
