//! Containerization layer (paper §2.3): all 16 pipelines run as
//! Singularity images stored in an archive reachable from every compute
//! node; any user can execute them without admin permissions.
//!
//! medflow's images are content-addressed bundles: a JSON build definition
//! (pipeline name, version, base env, entrypoint artifact) plus a payload
//! hash. "Running" an image means executing its HLO artifact through the
//! PJRT runtime with the environment pinned by the definition — which is
//! exactly the reproducibility property containers buy the paper.

pub mod platforms;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::integrity::sha256_hex;
use crate::util::json::{Json, JsonObj};

/// Build definition of a container image (what a .def/Dockerfile pins).
#[derive(Debug, Clone, PartialEq)]
pub struct ImageDef {
    pub pipeline: String,
    pub version: String,
    /// Base environment tag (e.g. "ubuntu22.04+xla0.5.1").
    pub base_env: String,
    /// HLO artifact the image's entrypoint executes (None for pure-CLI
    /// utility pipelines).
    pub artifact: Option<String>,
}

impl ImageDef {
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.set("Pipeline", Json::str(&self.pipeline));
        o.set("Version", Json::str(&self.version));
        o.set("BaseEnv", Json::str(&self.base_env));
        if let Some(a) = &self.artifact {
            o.set("Artifact", Json::str(a));
        }
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            pipeline: j
                .get_path("Pipeline")
                .and_then(Json::as_str)
                .context("missing Pipeline")?
                .into(),
            version: j
                .get_path("Version")
                .and_then(Json::as_str)
                .context("missing Version")?
                .into(),
            base_env: j
                .get_path("BaseEnv")
                .and_then(Json::as_str)
                .context("missing BaseEnv")?
                .into(),
            artifact: j.get_path("Artifact").and_then(Json::as_str).map(String::from),
        })
    }

    /// Canonical image file name (`<pipeline>_<version>.sif`).
    pub fn sif_name(&self) -> String {
        format!("{}_{}.sif", self.pipeline, self.version)
    }
}

/// A built image: definition + content hash.
#[derive(Debug, Clone, PartialEq)]
pub struct ContainerImage {
    pub def: ImageDef,
    pub sha256: String,
}

/// The Singularity image archive (one directory visible to all nodes).
#[derive(Debug)]
pub struct ContainerArchive {
    pub dir: PathBuf,
    index: BTreeMap<String, ContainerImage>,
}

impl ContainerArchive {
    pub fn open(dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)?;
        let mut archive = Self {
            dir: dir.to_path_buf(),
            index: BTreeMap::new(),
        };
        // Re-index existing images (idempotent re-open).
        for entry in std::fs::read_dir(dir)?.flatten() {
            let p = entry.path();
            if p.extension().map(|e| e == "sif").unwrap_or(false) {
                if let Ok(img) = read_image(&p) {
                    archive.index.insert(img.def.sif_name(), img);
                }
            }
        }
        Ok(archive)
    }

    /// Build + store an image. Deterministic: same def → same sha.
    pub fn build(&mut self, def: ImageDef) -> Result<ContainerImage> {
        let name = def.sif_name();
        if self.index.contains_key(&name) {
            bail!("image '{name}' already in archive (immutable images; bump the version)");
        }
        let payload = def.to_json().to_string_pretty();
        let sha256 = sha256_hex(payload.as_bytes());
        std::fs::write(self.dir.join(&name), &payload)?;
        let img = ContainerImage { def, sha256 };
        self.index.insert(name, img.clone());
        Ok(img)
    }

    /// Look up by pipeline name: returns the newest version (lexicographic,
    /// which works for the zero-padded versions medflow uses).
    pub fn latest(&self, pipeline: &str) -> Option<&ContainerImage> {
        self.index
            .values()
            .filter(|img| img.def.pipeline == pipeline)
            .max_by(|a, b| a.def.version.cmp(&b.def.version))
    }

    pub fn get(&self, sif_name: &str) -> Option<&ContainerImage> {
        self.index.get(sif_name)
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Verify every stored image still matches its content hash (bit-rot /
    /// tamper check before a processing campaign).
    pub fn fsck(&self) -> Result<Vec<String>> {
        let mut bad = Vec::new();
        for (name, img) in &self.index {
            let bytes = std::fs::read(self.dir.join(name))?;
            if sha256_hex(&bytes) != img.sha256 {
                bad.push(name.clone());
            }
        }
        Ok(bad)
    }
}

fn read_image(path: &Path) -> Result<ContainerImage> {
    let bytes = std::fs::read(path)?;
    let def = ImageDef::from_json(&Json::parse(std::str::from_utf8(&bytes)?)?)?;
    Ok(ContainerImage {
        def,
        sha256: sha256_hex(&bytes),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("medflow_cont_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn def(pipeline: &str, version: &str) -> ImageDef {
        ImageDef {
            pipeline: pipeline.into(),
            version: version.into(),
            base_env: "ubuntu22.04+xla0.5.1".into(),
            artifact: Some("seg_pipeline".into()),
        }
    }

    #[test]
    fn build_and_lookup() {
        let dir = tmp("build");
        let mut a = ContainerArchive::open(&dir).unwrap();
        let img = a.build(def("freesurfer", "7.2.0")).unwrap();
        assert_eq!(img.def.sif_name(), "freesurfer_7.2.0.sif");
        assert_eq!(a.latest("freesurfer").unwrap().sha256, img.sha256);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn images_immutable() {
        let dir = tmp("immut");
        let mut a = ContainerArchive::open(&dir).unwrap();
        a.build(def("prequal", "1.0.0")).unwrap();
        assert!(a.build(def("prequal", "1.0.0")).is_err());
        a.build(def("prequal", "1.0.1")).unwrap(); // version bump OK
        assert_eq!(a.latest("prequal").unwrap().def.version, "1.0.1");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn deterministic_hash() {
        let d1 = tmp("hash1");
        let d2 = tmp("hash2");
        let h1 = ContainerArchive::open(&d1).unwrap().build(def("slant", "2.0")).unwrap().sha256;
        let h2 = ContainerArchive::open(&d2).unwrap().build(def("slant", "2.0")).unwrap().sha256;
        assert_eq!(h1, h2);
        std::fs::remove_dir_all(&d1).unwrap();
        std::fs::remove_dir_all(&d2).unwrap();
    }

    #[test]
    fn reopen_reindexes() {
        let dir = tmp("reopen");
        {
            let mut a = ContainerArchive::open(&dir).unwrap();
            a.build(def("unest", "1.0")).unwrap();
        }
        let a = ContainerArchive::open(&dir).unwrap();
        assert_eq!(a.len(), 1);
        assert!(a.latest("unest").is_some());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fsck_detects_tamper() {
        let dir = tmp("fsck");
        let mut a = ContainerArchive::open(&dir).unwrap();
        let img = a.build(def("freesurfer", "7.2.0")).unwrap();
        assert!(a.fsck().unwrap().is_empty());
        std::fs::write(dir.join(img.def.sif_name()), b"{tampered}").unwrap();
        assert_eq!(a.fsck().unwrap(), vec!["freesurfer_7.2.0.sif".to_string()]);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
