//! Capability model of pipeline-deployment methods — regenerates **Table 2**.
//!
//! Axes (paper Table 2): needs specific OS permissions, needs extensive
//! setup, promotes reproducible code, lightweight. Singularity's column is
//! why the paper picks it: no admin perms (runs under pre-configured SLURM
//! clusters), no orchestration-platform setup, reproducible, lightweight.

/// One deployment method's capability row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeploymentMethod {
    pub name: &'static str,
    pub needs_os_permissions: bool,
    pub extensive_setup: bool,
    pub reproducible: bool,
    pub lightweight: bool,
}

/// The six methods of Table 2, in paper order.
pub fn methods() -> Vec<DeploymentMethod> {
    vec![
        DeploymentMethod {
            name: "Singularity",
            needs_os_permissions: false,
            extensive_setup: false,
            reproducible: true,
            lightweight: true,
        },
        DeploymentMethod {
            name: "Docker",
            needs_os_permissions: true,
            extensive_setup: false,
            reproducible: true,
            lightweight: true,
        },
        DeploymentMethod {
            name: "Kubernetes",
            needs_os_permissions: true,
            extensive_setup: true,
            reproducible: true,
            lightweight: false,
        },
        DeploymentMethod {
            name: "BIDS-App",
            needs_os_permissions: true,
            extensive_setup: false,
            reproducible: true,
            lightweight: true,
        },
        DeploymentMethod {
            name: "NITRC-CE/VMs",
            needs_os_permissions: false,
            extensive_setup: false,
            reproducible: true,
            lightweight: false,
        },
        DeploymentMethod {
            name: "Local Install",
            needs_os_permissions: false,
            extensive_setup: false,
            reproducible: false,
            lightweight: true,
        },
    ]
}

/// Design-criteria score (criterion 4 in §1: reproducible deployment with
/// minimal effort/complexity); lower is better.
pub fn design_criteria_score(m: &DeploymentMethod) -> u32 {
    m.needs_os_permissions as u32
        + m.extensive_setup as u32
        + (!m.reproducible) as u32
        + (!m.lightweight) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_methods_in_paper_order() {
        let names: Vec<_> = methods().iter().map(|m| m.name).collect();
        assert_eq!(
            names,
            ["Singularity", "Docker", "Kubernetes", "BIDS-App", "NITRC-CE/VMs", "Local Install"]
        );
    }

    #[test]
    fn singularity_is_strictly_best() {
        let all = methods();
        let sing = &all[0];
        assert_eq!(design_criteria_score(sing), 0);
        for m in &all[1..] {
            assert!(design_criteria_score(m) > 0, "{}", m.name);
        }
    }

    #[test]
    fn only_local_install_not_reproducible() {
        for m in methods() {
            assert_eq!(m.reproducible, m.name != "Local Install", "{}", m.name);
        }
    }

    #[test]
    fn kubernetes_needs_setup_others_dont() {
        for m in methods() {
            assert_eq!(m.extensive_setup, m.name == "Kubernetes", "{}", m.name);
        }
    }
}
