//! Workload generation: the paper's 20-dataset catalog (Table 4) and the
//! MASiVar 6-scan Table 1 experiment, generated synthetically at reduced
//! byte scale (DESIGN.md §2: curation/query/scheduling logic depends on
//! structure — sessions, modalities, file counts — not voxel content).

use anyhow::Result;

use crate::archive::{Archive, EntityIndex, SecurityTier, SessionKey, DEFAULT_SHARDS};
use crate::bids::{BidsDataset, BidsName, Modality};
use crate::convert::convert_series;
use crate::dicom::synth::{synth_series, SeriesSpec};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One row of the paper's Table 4 catalog (ground truth at paper scale).
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetCatalogEntry {
    pub name: &'static str,
    pub participants: u64,
    pub sessions: u64,
    pub size_tb: f64,
    pub raw_images: u64,
    pub total_files: u64,
    pub tier: SecurityTier,
}

/// The 20 datasets of Table 4, in paper order. UKBB is the GDPR-tier
/// dataset (paper §4 names UKBB's additional security requirements).
pub fn catalog() -> Vec<DatasetCatalogEntry> {
    use SecurityTier::*;
    let e = |name, participants, sessions, size_tb, raw_images, total_files, tier| {
        DatasetCatalogEntry {
            name,
            participants,
            sessions,
            size_tb,
            raw_images,
            total_files,
            tier,
        }
    };
    vec![
        e("ABVIB", 188, 227, 0.2, 284, 69_499, General),
        e("ADNI", 2618, 11_190, 47.0, 25_524, 14_550_555, General),
        e("BIOCARD", 212, 504, 8.4, 3003, 1_180_884, General),
        e("BLSA", 1151, 3962, 65.0, 19_043, 9_356_630, General),
        e("CAMCAN", 641, 641, 0.4, 1282, 36_537, General),
        e("HABS-HD", 4259, 6496, 1.1, 18_675, 469_071, General),
        e("HCP-Aging", 725, 725, 15.0, 1454, 1_727_081, General),
        e("HCP-Baby", 213, 418, 2.1, 1938, 362_416, General),
        e("HCP-Development", 635, 635, 2.2, 1271, 625_552, General),
        e("HCP-YoungAdult", 1206, 1206, 4.5, 2253, 1_644_656, General),
        e("ICBM", 193, 193, 2.4, 1168, 828_946, General),
        e("MAP", 589, 1579, 12.0, 3158, 2_157_929, General),
        e("MARS", 184, 347, 2.7, 694, 474_225, General),
        e("NACC", 5739, 7831, 16.0, 13_312, 3_826_519, General),
        e("OASIS3", 992, 1687, 8.1, 8164, 1_375_463, General),
        e("OASIS4", 661, 674, 4.1, 3942, 1_202_282, General),
        e("ROS", 77, 127, 1.0, 254, 173_564, General),
        e("UKBB", 10_439, 10_439, 79.0, 29_525, 18_734_690, Gdpr),
        e("VMAP", 769, 1805, 9.6, 4708, 2_046_778, General),
        e("WRAP", 612, 1625, 7.1, 3769, 1_831_795, General),
    ]
}

/// Totals row of Table 4.
pub fn catalog_totals() -> (u64, u64, f64, u64, u64) {
    let mut t = (0, 0, 0.0, 0, 0);
    for e in catalog() {
        t.0 += e.participants;
        t.1 += e.sessions;
        t.2 += e.size_tb;
        t.3 += e.raw_images;
        t.4 += e.total_files;
    }
    t
}

/// A generated synthetic cohort (scaled down from a catalog entry).
#[derive(Debug, Clone)]
pub struct SynthCohort {
    pub name: String,
    pub participants: u64,
    pub sessions: u64,
    pub tier: SecurityTier,
}

/// Scale a catalog entry down for simulation: `scale` in (0, 1]; at least
/// one participant/session survives.
pub fn scale_entry(e: &DatasetCatalogEntry, scale: f64) -> SynthCohort {
    let participants = ((e.participants as f64 * scale).round() as u64).max(1);
    // preserve the sessions-per-participant ratio
    let spp = e.sessions as f64 / e.participants as f64;
    let sessions = ((participants as f64 * spp).round() as u64).max(participants);
    SynthCohort {
        name: e.name.to_string(),
        participants,
        sessions,
        tier: e.tier,
    }
}

/// Ingest a synthetic cohort: synthesize DICOM per session, convert to
/// NIfTI + sidecar, store raw files in the archive, link into a BIDS tree.
/// Returns the BIDS dataset. `dim` is the synthetic matrix size (keep it
/// small; structure is what matters).
pub fn ingest_cohort(
    archive: &mut Archive,
    bids_parent: &std::path::Path,
    cohort: &SynthCohort,
    dim: u16,
    seed: u64,
) -> Result<BidsDataset> {
    archive.register_dataset(&cohort.name, cohort.tier)?;
    let ds = BidsDataset::create(bids_parent, &cohort.name)?;
    let mut index = EntityIndex::new(DEFAULT_SHARDS);
    for_each_session(cohort, seed, |p, s, subject, ses_label, has_t1, has_dwi, rng| {
        let date = format!("202{}010{}", 1 + (s % 3), 1 + (p % 9));
        if has_t1 {
            ingest_series(
                archive,
                &ds,
                &SeriesSpec::t1w(subject, &date, dim),
                subject,
                Some(ses_label),
                Modality::T1w,
                rng.next_u64(),
            )?;
        }
        if has_dwi {
            ingest_series(
                archive,
                &ds,
                &SeriesSpec::dwi(subject, &date, dim, 1000.0),
                subject,
                Some(ses_label),
                Modality::Dwi,
                rng.next_u64(),
            )?;
        }
        if !has_t1 && !has_dwi {
            // session exists but holds only filtered-out protocols:
            // still create the session dir so the query sees it
            let name = BidsName::new(subject, Some(ses_label), Modality::T1w);
            std::fs::create_dir_all(ds.raw_dir(&name).parent().unwrap())?;
        }
        // maintain the entity index as data lands: O(1) per session,
        // so campaigns never pay for a full tree walk (DESIGN.md §6)
        index.record_session(&ds, &SessionKey::new(subject, Some(ses_label)));
        Ok(())
    })?;
    index.save_for(&ds)?;
    // top-level demographics table (BIDS participants.tsv)
    crate::bids::participants::write_for_dataset(&ds, seed ^ 0xBEEF)?;
    Ok(ds)
}

/// Structure-only ingest for query/scheduling experiments at catalog
/// scale: creates the BIDS tree with stub image bytes (no DICOM synthesis,
/// no archive store, no symlinks) plus minimal sidecars, and persists the
/// sharded entity index. Orders of magnitude faster than [`ingest_cohort`]
/// — what the Table 4–scale query benchmarks use (DESIGN.md §2: curation
/// logic depends on structure, not voxel content).
pub fn ingest_cohort_lite(
    bids_parent: &std::path::Path,
    cohort: &SynthCohort,
    seed: u64,
) -> Result<BidsDataset> {
    let ds = BidsDataset::create(bids_parent, &cohort.name)?;
    let mut index = EntityIndex::new(DEFAULT_SHARDS);
    for_each_session(cohort, seed, |_p, _s, subject, ses_label, has_t1, has_dwi, _rng| {
        for (present, modality) in [(has_t1, Modality::T1w), (has_dwi, Modality::Dwi)] {
            if !present {
                continue;
            }
            let name = BidsName::new(subject, Some(ses_label), modality);
            let img = ds.raw_path(&name, "nii.gz");
            std::fs::create_dir_all(img.parent().unwrap())?;
            std::fs::write(&img, b"stub")?;
            let mut sidecar = Json::obj();
            sidecar.set("Modality", Json::str(modality.suffix()));
            std::fs::write(
                ds.raw_dir(&name).join(format!("{}.json", name.format())),
                Json::Obj(sidecar).to_string_pretty(),
            )?;
        }
        if !has_t1 && !has_dwi {
            let name = BidsName::new(subject, Some(ses_label), Modality::T1w);
            std::fs::create_dir_all(ds.raw_dir(&name).parent().unwrap())?;
        }
        index.record_session(&ds, &SessionKey::new(subject, Some(ses_label)));
        Ok(())
    })?;
    index.save_for(&ds)?;
    Ok(ds)
}

/// Shared cohort-shape skeleton of [`ingest_cohort`] and
/// [`ingest_cohort_lite`]: distribute sessions across participants (base
/// per participant, remainder to the first few), draw the per-session
/// modality mix (90% of sessions have T1w, 60% have DWI — the misses are
/// what feed the skip CSV), and hand every session to `per_session`.
fn for_each_session(
    cohort: &SynthCohort,
    seed: u64,
    mut per_session: impl FnMut(u64, u64, &str, &str, bool, bool, &mut Rng) -> Result<()>,
) -> Result<()> {
    let mut rng = Rng::new(seed);
    let base = (cohort.sessions / cohort.participants).max(1);
    let extra = cohort.sessions.saturating_sub(base * cohort.participants);
    for p in 0..cohort.participants {
        let subject = format!("{:04}", p + 1);
        let for_this = base + u64::from(p < extra);
        for s in 0..for_this {
            let ses_label = format!("{}", s + 1);
            let has_t1 = rng.next_f64() < 0.9;
            let has_dwi = rng.next_f64() < 0.6;
            per_session(p, s, &subject, &ses_label, has_t1, has_dwi, &mut rng)?;
        }
    }
    Ok(())
}

/// Generate the whole Table 4 catalog as lite cohorts at `scale` (each
/// entry scaled by [`scale_entry`]) under one parent directory — the
/// multi-dataset, multi-shard workload the sharded query engine is
/// benchmarked against.
pub fn ingest_catalog_lite(
    bids_parent: &std::path::Path,
    scale: f64,
    seed: u64,
) -> Result<Vec<BidsDataset>> {
    let mut out = Vec::new();
    for (i, entry) in catalog().iter().enumerate() {
        let cohort = scale_entry(entry, scale);
        out.push(ingest_cohort_lite(bids_parent, &cohort, seed.wrapping_add(i as u64))?);
    }
    Ok(out)
}

fn ingest_series(
    archive: &mut Archive,
    ds: &BidsDataset,
    spec: &SeriesSpec,
    subject: &str,
    session: Option<&str>,
    modality: Modality,
    seed: u64,
) -> Result<()> {
    let slices = synth_series(spec, seed);
    let converted = convert_series(&slices)?;
    let name = BidsName::new(subject, session, modality);
    let rel = format!("{}/{}.nii.gz", subject, name.format());
    let nii_bytes = {
        // write via NiftiImage::save into a temp then read — or serialize directly
        converted.image.to_nii_bytes()?
    };
    // store compressed raw in the archive (gzip via save path)
    let tmp =
        std::env::temp_dir().join(format!("medflow_ingest_{}_{}.nii.gz", std::process::id(), seed));
    converted.image.save(&tmp)?;
    let stored = archive.store_raw(&ds.name, &rel, &std::fs::read(&tmp)?)?;
    std::fs::remove_file(&tmp).ok();
    drop(nii_bytes);
    // sidecar next to the raw file
    let sidecar_rel = format!("{}/{}.json", subject, name.format());
    let sidecar_stored =
        archive.store_raw(&ds.name, &sidecar_rel, converted.sidecar.to_string_pretty().as_bytes())?;
    // link into BIDS tree
    ds.link_raw(&name, "nii.gz", &stored)?;
    let sidecar_link = ds.raw_dir(&name).join(format!("{}.json", name.format()));
    std::fs::create_dir_all(sidecar_link.parent().unwrap())?;
    if sidecar_link.symlink_metadata().is_ok() {
        std::fs::remove_file(&sidecar_link).ok();
    }
    #[cfg(unix)]
    std::os::unix::fs::symlink(&sidecar_stored, &sidecar_link)?;
    #[cfg(not(unix))]
    std::fs::copy(&sidecar_stored, &sidecar_link)?;
    Ok(())
}

/// The Table 1 experiment workload: six T1w scans from a MASiVar-like
/// mini-cohort (paper §2.4). Returns the generated 64³ volumes.
pub fn masivar_six_scans(seed: u64) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(6);
    for i in 0..6 {
        let spec = SeriesSpec::t1w(&format!("{:02}", i + 1), "20240101", 64);
        let slices = synth_series(&spec, seed.wrapping_add(i as u64));
        let conv = convert_series(&slices).expect("synth series converts");
        // normalize u16 intensities to [0,1] f32 for the seg artifact
        let max = conv.image.data.iter().cloned().fold(1.0f32, f32::max);
        out.push(conv.image.data.iter().map(|&v| v / max).collect());
    }
    out
}

/// Ground-truth sidecar check helper (used by tests/examples).
pub fn sidecar_is_valid(text: &str) -> bool {
    Json::parse(text)
        .map(|j| j.get_path("Modality").is_some())
        .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bids::validate_dataset;
    use crate::query::find_runnable;

    #[test]
    fn catalog_matches_table4_totals() {
        let (participants, sessions, tb, raw, files) = catalog_totals();
        assert_eq!(participants, 32_103);
        assert_eq!(sessions, 52_311);
        assert!((tb - 287.9).abs() < 0.01, "tb={tb}");
        assert_eq!(raw, 143_421);
        assert_eq!(files, 62_675_072);
    }

    #[test]
    fn twenty_datasets_one_gdpr() {
        let c = catalog();
        assert_eq!(c.len(), 20);
        let gdpr: Vec<_> = c.iter().filter(|e| e.tier == SecurityTier::Gdpr).collect();
        assert_eq!(gdpr.len(), 1);
        assert_eq!(gdpr[0].name, "UKBB");
    }

    #[test]
    fn scaling_preserves_session_ratio() {
        let adni = &catalog()[1];
        let c = scale_entry(adni, 0.001);
        assert!(c.participants >= 1);
        let ratio = c.sessions as f64 / c.participants as f64;
        let want = adni.sessions as f64 / adni.participants as f64;
        assert!((ratio - want).abs() < 1.5, "ratio {ratio} want {want}");
    }

    #[test]
    fn ingest_produces_valid_bids_with_symlinks() {
        let root = std::env::temp_dir().join(format!("medflow_wl_{}", std::process::id()));
        std::fs::create_dir_all(&root).unwrap();
        let mut archive = Archive::at(&root.join("store")).unwrap();
        let cohort = SynthCohort {
            name: "MINI".into(),
            participants: 3,
            sessions: 4,
            tier: SecurityTier::General,
        };
        let ds = ingest_cohort(&mut archive, &root.join("bids"), &cohort, 8, 42).unwrap();
        let errors: Vec<_> = validate_dataset(&ds.root)
            .into_iter()
            .filter(|i| i.severity == crate::bids::Severity::Error)
            .collect();
        assert!(errors.is_empty(), "{errors:?}");
        assert_eq!(ds.subjects().unwrap().len(), 3);
        // raw images are symlinks into the store
        let mut found_link = false;
        for sub in ds.subjects().unwrap() {
            for ses in ds.sessions(&sub).unwrap() {
                let name = BidsName::new(&sub, ses.as_deref(), Modality::T1w);
                for img in ds.raw_images(&name) {
                    assert!(img.symlink_metadata().unwrap().file_type().is_symlink());
                    found_link = true;
                }
            }
        }
        assert!(found_link);
        // query engine sees the cohort
        let fs = crate::pipeline::by_name("freesurfer").unwrap();
        let q = find_runnable(&ds, &fs).unwrap();
        assert!(!q.runnable.is_empty());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn lite_ingest_builds_persistent_index_matching_full_scan() {
        let root = std::env::temp_dir().join(format!("medflow_lite_{}", std::process::id()));
        std::fs::create_dir_all(&root).unwrap();
        let cohort = SynthCohort {
            name: "LITE".into(),
            participants: 5,
            sessions: 10,
            tier: SecurityTier::General,
        };
        let ds = ingest_cohort_lite(&root, &cohort, 9).unwrap();
        let index = EntityIndex::load(&ds.index_dir().join("index")).unwrap();
        assert_eq!(index.len(), 10);
        // sharded query over the persisted index agrees with the full scan
        let fs = crate::pipeline::by_name("freesurfer").unwrap();
        let full = find_runnable(&ds, &fs).unwrap();
        let processed = crate::archive::ProcessedIndex::default();
        let (sharded, stats) =
            crate::query::find_runnable_sharded(&ds, &fs, &index, &processed, 4).unwrap();
        assert_eq!(sharded.runnable, full.runnable);
        assert_eq!(sharded.skipped, full.skipped);
        assert!(stats.shards_scanned >= 1);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn catalog_lite_generates_all_twenty() {
        let root = std::env::temp_dir().join(format!("medflow_cat_{}", std::process::id()));
        std::fs::create_dir_all(&root).unwrap();
        let sets = ingest_catalog_lite(&root, 0.001, 3).unwrap();
        assert_eq!(sets.len(), 20);
        for ds in &sets {
            assert!(ds.index_dir().join("index").join("meta.json").exists(), "{}", ds.name);
        }
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn masivar_six_volumes_shape() {
        let vols = masivar_six_scans(1);
        assert_eq!(vols.len(), 6);
        for v in &vols {
            assert_eq!(v.len(), 64 * 64 * 64);
            assert!(v.iter().all(|x| (0.0..=1.0).contains(x)));
        }
        // distinct scans (different noise)
        assert_ne!(vols[0], vols[1]);
    }

    #[test]
    fn ingest_deterministic_by_seed() {
        let mk = |tag: &str| {
            let root =
                std::env::temp_dir().join(format!("medflow_det_{tag}_{}", std::process::id()));
            std::fs::create_dir_all(&root).unwrap();
            let mut archive = Archive::at(&root.join("store")).unwrap();
            let cohort = SynthCohort {
                name: "MINI".into(),
                participants: 2,
                sessions: 2,
                tier: SecurityTier::General,
            };
            let ds = ingest_cohort(&mut archive, &root.join("bids"), &cohort, 8, 7).unwrap();
            let subs = ds.subjects().unwrap();
            let usage = archive.usage("MINI").unwrap();
            std::fs::remove_dir_all(&root).unwrap();
            (subs, usage.file_count)
        };
        assert_eq!(mk("a"), mk("b"));
    }
}
