//! Reproduction gate: machine-checkable paper-vs-measured assertions for
//! every headline number. `rust/tests/reproduction_gate.rs` runs this in
//! CI fashion — if a change breaks the reproduction *shape* (who wins, by
//! what factor), the gate fails before anything ships.

use anyhow::Result;

use crate::report::{paper, table1, Table1Column};
use crate::runtime::Runtime;

/// One gate check outcome.
#[derive(Debug, Clone)]
pub struct GateCheck {
    pub name: String,
    pub paper: f64,
    pub measured: f64,
    pub tolerance: f64,
    pub pass: bool,
}

impl GateCheck {
    fn rel(name: &str, paper_v: f64, measured: f64, rel_tol: f64) -> Self {
        let pass = (measured - paper_v).abs() <= rel_tol * paper_v.abs().max(1e-12);
        Self {
            name: name.to_string(),
            paper: paper_v,
            measured,
            tolerance: rel_tol,
            pass,
        }
    }

    fn ordering(name: &str, holds: bool) -> Self {
        Self {
            name: name.to_string(),
            paper: 1.0,
            measured: if holds { 1.0 } else { 0.0 },
            tolerance: 0.0,
            pass: holds,
        }
    }
}

/// Run the full gate (Table 1 experiment + shape claims).
pub fn run_gate(runtime: Option<&Runtime>, seed: u64) -> Result<Vec<GateCheck>> {
    let cols = table1(runtime, seed, 100, 100)?;
    Ok(checks_for(&cols))
}

/// Gate checks over a measured Table 1.
pub fn checks_for(cols: &[Table1Column]) -> Vec<GateCheck> {
    let hpc = &cols[0];
    let cloud = &cols[1];
    let local = &cols[2];
    let mut checks = vec![
        // absolute calibrations (10% relative)
        GateCheck::rel("hpc.throughput_gbps", paper::HPC.0, hpc.throughput_gbps.0, 0.10),
        GateCheck::rel("cloud.throughput_gbps", paper::CLOUD.0, cloud.throughput_gbps.0, 0.10),
        GateCheck::rel("local.throughput_gbps", paper::LOCAL.0, local.throughput_gbps.0, 0.10),
        GateCheck::rel("cloud.latency_ms", paper::CLOUD.1, cloud.latency_ms.0, 0.10),
        GateCheck::rel("hpc.rate_per_hr", paper::HPC.2, hpc.dollars_per_hour, 0.02),
        GateCheck::rel("cloud.rate_per_hr", paper::CLOUD.2, cloud.dollars_per_hour, 0.001),
        GateCheck::rel("local.rate_per_hr", paper::LOCAL.2, local.dollars_per_hour, 0.02),
        GateCheck::rel("hpc.freesurfer_min", paper::HPC.3, hpc.freesurfer_minutes.0, 0.05),
        GateCheck::rel("cloud.freesurfer_min", paper::CLOUD.3, cloud.freesurfer_minutes.0, 0.05),
        GateCheck::rel("local.freesurfer_min", paper::LOCAL.3, local.freesurfer_minutes.0, 0.05),
        GateCheck::rel("hpc.total_cost", paper::HPC.4, hpc.total_cost_dollars, 0.15),
        GateCheck::rel("cloud.total_cost", paper::CLOUD.4, cloud.total_cost_dollars, 0.10),
        GateCheck::rel("local.total_cost", paper::LOCAL.4, local.total_cost_dollars, 0.10),
    ];
    // shape claims (orderings + factors)
    let cost_ratio = cloud.total_cost_dollars / hpc.total_cost_dollars;
    checks.push(GateCheck::rel("cloud_over_hpc_cost_ratio", 18.3, cost_ratio, 0.15));
    checks.push(GateCheck::ordering(
        "bandwidth ordering local > hpc > cloud",
        local.throughput_gbps.0 > hpc.throughput_gbps.0
            && hpc.throughput_gbps.0 > cloud.throughput_gbps.0,
    ));
    checks.push(GateCheck::ordering(
        "latency ordering cloud >> local > hpc",
        cloud.latency_ms.0 > 10.0 * local.latency_ms.0 && local.latency_ms.0 > hpc.latency_ms.0,
    ));
    checks.push(GateCheck::ordering(
        "cloud fastest compute, local slowest",
        cloud.freesurfer_minutes.0 < hpc.freesurfer_minutes.0
            && hpc.freesurfer_minutes.0 < local.freesurfer_minutes.0,
    ));
    checks
}

/// Render the gate result; Err text lists failures.
pub fn summarize(checks: &[GateCheck]) -> Result<String, String> {
    let mut out = String::new();
    let mut failures = 0;
    for c in checks {
        out.push_str(&format!(
            "{:<42} paper {:>9.4}  measured {:>9.4}  {}\n",
            c.name,
            c.paper,
            c.measured,
            if c.pass { "PASS" } else { "FAIL" }
        ));
        failures += usize::from(!c.pass);
    }
    if failures == 0 {
        Ok(out)
    } else {
        Err(format!("{failures} gate checks failed:\n{out}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_passes_on_calibrated_models() {
        let checks = run_gate(None, 42).unwrap();
        let summary = summarize(&checks);
        assert!(summary.is_ok(), "{}", summary.unwrap_err());
        assert!(checks.len() >= 17);
    }

    #[test]
    fn gate_catches_a_broken_calibration() {
        let mut cols = table1(None, 42, 50, 50).unwrap();
        cols[0].total_cost_dollars *= 3.0; // sabotage
        let checks = checks_for(&cols);
        assert!(summarize(&checks).is_err());
    }

    #[test]
    fn rel_check_math() {
        assert!(GateCheck::rel("x", 10.0, 10.5, 0.10).pass);
        assert!(!GateCheck::rel("x", 10.0, 12.0, 0.10).pass);
    }
}
