//! Report generation: regenerates every table and figure of the paper's
//! evaluation (DESIGN.md §3 experiment index).
//!
//! * [`table1`] — compute-environment comparison (throughput, latency,
//!   $/hr, Freesurfer minutes, total campaign cost).
//! * [`table2`] — deployment-method criteria matrix.
//! * [`table3`] — archival-solution criteria matrix.
//! * [`table4`] — dataset inventory over ingested synthetic cohorts.
//! * [`fig1`] — cost/complexity/bandwidth/efficiency tradeoff quadrants.

pub mod gate;

use anyhow::Result;

use crate::archive::{solutions, Archive};
use crate::container::platforms;
use crate::cost::{compute_cost, instance_hourly_rate};
use crate::netsim::scheduler::{TransferRecord, TransferStats};
use crate::netsim::{bandwidth_experiment, latency_experiment, Env};
use crate::pipeline::by_name;
use crate::runtime::Runtime;
use crate::util::csv::write_csv;
use crate::util::rng::Rng;
use crate::util::units::{fmt_duration, mean_std, percentiles};
use crate::workload::masivar_six_scans;

/// One Table 1 column (an environment's measured row values).
#[derive(Debug, Clone)]
pub struct Table1Column {
    pub env: Env,
    pub throughput_gbps: (f64, f64),
    pub latency_ms: (f64, f64),
    pub dollars_per_hour: f64,
    pub freesurfer_minutes: (f64, f64),
    pub total_cost_dollars: f64,
    /// Real measured PJRT seconds per scan (the artifact actually ran).
    pub artifact_exec_s: f64,
}

/// Run the §2.4 experiment: 6 MASiVar T1w scans through the
/// Freesurfer-like pipeline in each environment; 1 GB × `n_copies`
/// bandwidth probe; 64 B × `n_pings` latency probe.
pub fn table1(
    runtime: Option<&Runtime>,
    seed: u64,
    n_copies: usize,
    n_pings: usize,
) -> Result<Vec<Table1Column>> {
    let spec = by_name("freesurfer").expect("registry has freesurfer");
    let scans = masivar_six_scans(seed);
    let mut cols = Vec::new();
    for env in Env::all() {
        let bw = bandwidth_experiment(env, n_copies, seed);
        let lat = latency_experiment(env, n_pings, seed ^ 1);
        let mut rng = Rng::new(seed ^ 2);
        let factor = crate::compute::env_speed_factor(env);
        let mut minutes = Vec::new();
        let mut exec_s = Vec::new();
        for vol in &scans {
            minutes.push(spec.sample_minutes(&mut rng) / factor);
            if let Some(rt) = runtime {
                let t0 = std::time::Instant::now();
                let out = rt.run_seg(vol)?;
                exec_s.push(t0.elapsed().as_secs_f64());
                debug_assert!(out.volumes.iter().sum::<f32>() > 0.0);
            }
        }
        let total_cost: f64 = minutes.iter().map(|&m| compute_cost(env, m)).sum();
        cols.push(Table1Column {
            env,
            throughput_gbps: mean_std(&bw),
            latency_ms: mean_std(&lat),
            dollars_per_hour: instance_hourly_rate(env),
            freesurfer_minutes: mean_std(&minutes),
            total_cost_dollars: total_cost,
            artifact_exec_s: if exec_s.is_empty() {
                0.0
            } else {
                exec_s.iter().sum::<f64>() / exec_s.len() as f64
            },
        });
    }
    Ok(cols)
}

/// Format Table 1 like the paper.
pub fn format_table1(cols: &[Table1Column]) -> String {
    let mut s = String::new();
    s.push_str("Table 1. Cost and performance metrics for three computation environments\n");
    s.push_str(&format!(
        "{:<46}{:>16}{:>22}{:>12}\n",
        "Metric", "HPC (ACCRE)", "Cloud (AWS t2.xlarge)", "Local"
    ));
    let col = |f: &dyn Fn(&Table1Column) -> String| -> Vec<String> {
        cols.iter().map(|c| f(c)).collect()
    };
    let rows: Vec<(&str, Vec<String>)> = vec![
        (
            "Avg data throughput (Gb/s ± stdev)",
            col(&|c| format!("{:.2} ± {:.2}", c.throughput_gbps.0, c.throughput_gbps.1)),
        ),
        (
            "Latency, 64 B (ms ± stdev)",
            col(&|c| format!("{:.2} ± {:.2}", c.latency_ms.0, c.latency_ms.1)),
        ),
        (
            "Cost per hr compute ($, single instance)",
            col(&|c| format!("{:.4}", c.dollars_per_hour)),
        ),
        (
            "Avg time to run Freesurfer (mins ± stdev)",
            col(&|c| format!("{:.1} ± {:.1}", c.freesurfer_minutes.0, c.freesurfer_minutes.1)),
        ),
        (
            "Total overhead cost, 6 scans ($)",
            col(&|c| format!("{:.2}", c.total_cost_dollars)),
        ),
        (
            "Measured PJRT exec per scan (s)",
            col(&|c| format!("{:.3}", c.artifact_exec_s)),
        ),
    ];
    for (name, vals) in rows {
        s.push_str(&format!(
            "{:<46}{:>16}{:>22}{:>12}\n",
            name, vals[0], vals[1], vals[2]
        ));
    }
    s
}

/// Table 2 as formatted text (capability matrix from the container model).
pub fn format_table2() -> String {
    let yn = |b: bool| if b { "Yes" } else { "No" };
    let methods = platforms::methods();
    let mut s = String::from("Table 2. Pipeline deployment methods\n");
    s.push_str(&format!("{:<28}", "Metric"));
    for m in &methods {
        s.push_str(&format!("{:>14}", m.name));
    }
    s.push('\n');
    let rows: Vec<(&str, Box<dyn Fn(&platforms::DeploymentMethod) -> bool>)> = vec![
        ("OS permissions required", Box::new(|m| m.needs_os_permissions)),
        ("Extensive setup", Box::new(|m| m.extensive_setup)),
        ("Reproducible code", Box::new(|m| m.reproducible)),
        ("Lightweight", Box::new(|m| m.lightweight)),
    ];
    for (name, f) in rows {
        s.push_str(&format!("{name:<28}"));
        for m in &methods {
            s.push_str(&format!("{:>14}", yn(f(m))));
        }
        s.push('\n');
    }
    s
}

/// Table 3 as formatted text (capability matrix from the archive model).
pub fn format_table3() -> String {
    let yn = |b: bool| if b { "Yes" } else { "No" };
    let sols = solutions::solutions();
    let mut s = String::from("Table 3. Data archival solutions\n");
    s.push_str(&format!("{:<26}", "Metric"));
    for x in &sols {
        s.push_str(&format!("{:>11}", x.name));
    }
    s.push('\n');
    let rows: Vec<(&str, Box<dyn Fn(&solutions::ArchivalSolution) -> bool>)> = vec![
        ("Requires credentials", Box::new(|x| x.requires_credentials)),
        ("Data-use conflicts", Box::new(|x| x.data_use_conflicts)),
        ("Flexible structure", Box::new(|x| x.flexible_structure)),
    ];
    for (name, f) in rows {
        s.push_str(&format!("{name:<26}"));
        for x in &sols {
            s.push_str(&format!("{:>11}", yn(f(x))));
        }
        s.push('\n');
    }
    s
}

/// One Table 4 row measured from an ingested archive.
#[derive(Debug, Clone)]
pub struct Table4Row {
    pub dataset: String,
    pub participants: u64,
    pub sessions: u64,
    pub bytes: u64,
    pub raw_images: u64,
    pub total_files: u64,
}

/// Measure the inventory of every ingested dataset (the archive-side
/// regeneration of Table 4 at simulation scale).
pub fn table4(archive: &Archive, bids_parent: &std::path::Path) -> Result<Vec<Table4Row>> {
    let mut rows = Vec::new();
    for (name, _tier) in archive.datasets().collect::<Vec<_>>() {
        let usage = archive.usage(name)?;
        let ds = crate::bids::BidsDataset::open(&bids_parent.join(name))?;
        let subjects = ds.subjects()?;
        let mut sessions = 0u64;
        for sub in &subjects {
            sessions += ds.sessions(sub)?.len() as u64;
        }
        rows.push(Table4Row {
            dataset: name.to_string(),
            participants: subjects.len() as u64,
            sessions,
            bytes: usage.bytes,
            raw_images: usage.raw_image_count,
            total_files: usage.file_count,
        });
    }
    rows.sort_by(|a, b| a.dataset.cmp(&b.dataset));
    Ok(rows)
}

/// Format Table 4 with a totals row (paper layout).
pub fn format_table4(rows: &[Table4Row]) -> String {
    let mut s = String::from("Table 4. Neuroimaging database inventory (simulation scale)\n");
    s.push_str(&format!(
        "{:<18}{:>14}{:>10}{:>14}{:>12}{:>12}\n",
        "Dataset", "Participants", "Sessions", "Bytes", "Raw MRI", "Files"
    ));
    let mut t = (0u64, 0u64, 0u64, 0u64, 0u64);
    for r in rows {
        s.push_str(&format!(
            "{:<18}{:>14}{:>10}{:>14}{:>12}{:>12}\n",
            r.dataset, r.participants, r.sessions, r.bytes, r.raw_images, r.total_files
        ));
        t.0 += r.participants;
        t.1 += r.sessions;
        t.2 += r.bytes;
        t.3 += r.raw_images;
        t.4 += r.total_files;
    }
    s.push_str(&format!(
        "{:<18}{:>14}{:>10}{:>14}{:>12}{:>12}\n",
        "TOTAL", t.0, t.1, t.2, t.3, t.4
    ));
    s
}

/// Fig. 1 scores: each option scored on compute efficiency, bandwidth,
/// cost, and complexity (0–10, higher = more of that quantity). The
/// "adaptive" row is the paper's proposed method.
#[derive(Debug, Clone, PartialEq)]
pub struct Fig1Point {
    pub option: &'static str,
    pub compute_efficiency: f64,
    pub bandwidth: f64,
    pub cost: f64,
    pub complexity: f64,
}

/// Compute Fig. 1's qualitative quadrants from the quantitative models:
/// bandwidth from netsim, cost from the cost model (log-scaled), compute
/// efficiency from parallelizable capacity, complexity from the capability
/// models.
pub fn fig1(seed: u64) -> Vec<Fig1Point> {
    let bw = |env| mean_std(&bandwidth_experiment(env, 50, seed)).0;
    // cost score: normalized hourly cost on a log scale (cheap → low)
    let cost_score = |env| {
        let c = instance_hourly_rate(env);
        // map [0.0096, 0.1856] → roughly [1, 9]
        (c / 0.0096).log2().max(0.0) + 1.0
    };
    let scale_bw = |g: f64| g / 0.81 * 8.0; // local 0.81 Gb/s → 8
    vec![
        Fig1Point {
            option: "Local workstation",
            compute_efficiency: 1.5, // one job per box, no parallel scale
            bandwidth: scale_bw(bw(Env::Local)),
            cost: cost_score(Env::Local),
            complexity: 2.0,
        },
        Fig1Point {
            option: "Cloud",
            compute_efficiency: 9.0, // near-unbounded scale
            bandwidth: scale_bw(bw(Env::Cloud)),
            cost: cost_score(Env::Cloud) + 3.0, // + egress/setup overheads
            complexity: 7.0,                    // orchestration burden
        },
        Fig1Point {
            option: "Adaptive (ours)",
            compute_efficiency: 8.0, // 20k-core shared cluster
            bandwidth: scale_bw(bw(Env::Hpc)) + 2.0, // near-line 100 Gb fabric for bursts
            cost: cost_score(Env::Hpc),
            complexity: 3.0, // SLURM + singularity, no orchestration platform
        },
    ]
}

/// CSV of the Fig. 1 series (for external plotting).
pub fn fig1_csv(points: &[Fig1Point]) -> String {
    let rows = points
        .iter()
        .map(|p| {
            vec![
                p.option.to_string(),
                format!("{:.2}", p.compute_efficiency),
                format!("{:.2}", p.bandwidth),
                format!("{:.2}", p.cost),
                format!("{:.2}", p.complexity),
            ]
        })
        .collect::<Vec<_>>();
    write_csv(
        &["option", "compute_efficiency", "bandwidth", "cost", "complexity"],
        &rows,
    )
}

/// ASCII rendering of Fig. 1 (cost vs efficiency quadrant).
pub fn format_fig1(points: &[Fig1Point]) -> String {
    let mut s =
        String::from("Fig 1. Tradeoffs (cost→ vs compute efficiency↑; B=bandwidth, X=complexity)\n");
    for p in points {
        s.push_str(&format!(
            "{:<20} eff={:>4.1} bw={:>4.1} cost={:>4.1} cx={:>4.1}  ",
            p.option, p.compute_efficiency, p.bandwidth, p.cost, p.complexity
        ));
        let stars = "#".repeat(p.compute_efficiency.round() as usize);
        s.push_str(&format!("|{stars}\n"));
    }
    s
}

/// Render the transfer scheduler's per-stream records as a table
/// (`medflow transfer-sim`; DESIGN.md §9).
pub fn format_transfer_records(records: &[TransferRecord]) -> String {
    let mut rows = records.to_vec();
    rows.sort_by(|a, b| {
        (a.start_s, a.id)
            .partial_cmp(&(b.start_s, b.id))
            .expect("finite times")
    });
    let mut s = format!(
        "{:>4}{:>12}{:>12}{:>12}{:>12}{:>14}{:>14}\n",
        "id", "bytes", "wait", "start (s)", "end (s)", "wire time", "observed Gb/s"
    );
    for r in &rows {
        s.push_str(&format!(
            "{:>4}{:>12}{:>12}{:>12.3}{:>12.3}{:>14}{:>14.3}\n",
            r.id,
            crate::util::units::fmt_bytes(r.bytes),
            crate::util::units::fmt_duration(r.queue_wait_s()),
            r.start_s,
            r.end_s,
            crate::util::units::fmt_duration(r.transfer_s()),
            r.observed_gbps()
        ));
    }
    s
}

/// Queue-wait percentile row for transfer reports (`medflow
/// transfer-sim`): one sort serves every percentile
/// ([`percentiles`]) — campaign-sized record sets make per-percentile
/// re-sorting visible.
pub fn format_transfer_waits(records: &[TransferRecord]) -> String {
    let waits: Vec<f64> = records.iter().map(|r| r.queue_wait_s()).collect();
    let ps = percentiles(&waits, &[50.0, 90.0, 99.0]);
    format!(
        "queue wait p50 {}   p90 {}   p99 {}\n",
        fmt_duration(ps[0]),
        fmt_duration(ps[1]),
        fmt_duration(ps[2]),
    )
}

/// Render the in-engine fault telemetry of a campaign (DESIGN.md §11):
/// per-mode failed-attempt counts, retry/restage/abort traffic, the
/// wasted time both engines accounted, and the closed-form §4 overrun
/// cross-check.
pub fn format_fault_stats(f: &crate::faults::FaultTelemetry) -> String {
    let mut s = format!(
        "faults: {:>4} failed attempts (checksum {}, pipeline {}, node {}, timeout {})\n\
         retries: compute {} ({} re-staged), transfer {}   aborted {}\n\
         wasted: {:.1} compute-min, {} transfer   closed-form overrun ×{:.3}\n",
        f.counts.total(),
        f.counts.checksum,
        f.counts.pipeline,
        f.counts.node,
        f.counts.timeout,
        f.compute_retries,
        f.restages,
        f.transfer_retries,
        f.aborted,
        f.wasted_compute_minutes,
        fmt_duration(f.wasted_transfer_s),
        f.expected_overrun_factor,
    );
    // infrastructure-outage band (DESIGN.md §15), only when a chaos run
    // actually recorded something — fault-only reports stay unchanged
    if f.outage_kills > 0 || f.outage_orphans > 0 || f.outage_wasted_minutes > 0.0 {
        s.push_str(&format!(
            "outages: {} killed, {} orphaned, {:.1} compute-min wasted\n",
            f.outage_kills, f.outage_orphans, f.outage_wasted_minutes
        ));
    }
    s
}

/// Render a chaos run's infrastructure-outage telemetry (`medflow
/// chaos`; DESIGN.md §15): the injected schedule's size and what the
/// engines killed, orphaned, and re-placed under it.
pub fn format_outage(o: &crate::faults::outage::OutageStats) -> String {
    format!(
        "chaos: {} outage windows, {} brownouts   killed {}   orphaned {} ({} re-placed)   wasted {}\n",
        o.windows,
        o.brownouts,
        o.killed,
        o.orphaned,
        o.re_placed,
        fmt_duration(o.killed_wasted_s),
    )
}

/// Render a placement run's per-backend usage (`medflow place`,
/// `medflow campaign --placement`; DESIGN.md §12): where the policy
/// sent the jobs and what each environment's slot rate billed.
pub fn format_placement(
    policy: &str,
    usage: &[crate::coordinator::placement::BackendUsage],
) -> String {
    let mut s = format!("placement [{policy}]\n");
    s.push_str(&format!(
        "{:<10}{:<24}{:>7}{:>11}{:>14}{:>12}{:>9}{:>9}\n",
        "backend", "env", "jobs", "completed", "compute min", "cost ($)", "failed", "aborted"
    ));
    let (mut jobs, mut completed, mut minutes, mut cost) = (0usize, 0usize, 0.0f64, 0.0f64);
    for u in usage {
        s.push_str(&format!(
            "{:<10}{:<24}{:>7}{:>11}{:>14.1}{:>12.4}{:>9}{:>9}\n",
            u.name,
            u.env.name(),
            u.jobs,
            u.completed,
            u.compute_minutes,
            u.cost_dollars,
            u.failed_attempts,
            u.aborted
        ));
        jobs += u.jobs;
        completed += u.completed;
        minutes += u.compute_minutes;
        cost += u.cost_dollars;
    }
    s.push_str(&format!(
        "{:<10}{:<24}{:>7}{:>11}{:>14.1}{:>12.4}\n",
        "TOTAL", "", jobs, completed, minutes, cost
    ));
    s
}

/// Render a multi-tenant co-simulation's per-tenant telemetry
/// (`medflow tenants`; DESIGN.md §13). The per-tenant table caps at 16
/// rows — the sweeps run 10^3 tenants — and summarizes the remainder;
/// the TOTAL row always folds every tenant.
pub fn format_tenancy(report: &crate::coordinator::tenancy::TenancyReport) -> String {
    let depth = match report.queue_depth {
        Some(d) => format!("depth {d}"),
        None => "unbounded depth".to_string(),
    };
    let mut s = format!(
        "tenancy co-simulation [{} tenants, {depth}]\n",
        report.tenants.len()
    );
    s.push_str(&format!(
        "{:<14}{:>5}{:>8}{:>6}{:>6}{:>12}{:>11}{:>11}{:>11}{:>9}{:>9}\n",
        "tenant", "prio", "weight", "jobs", "done", "cost ($)", "makespan", "wait p50", "wait p95",
        "share%", "entl%"
    ));
    const MAX_ROWS: usize = 16;
    for u in report.tenants.iter().take(MAX_ROWS) {
        s.push_str(&format!(
            "{:<14}{:>5}{:>8.2}{:>6}{:>6}{:>12.4}{:>11}{:>11}{:>11}{:>9.2}{:>9.2}\n",
            u.name,
            u.priority,
            u.weight,
            u.jobs,
            u.completed,
            u.cost_dollars,
            fmt_duration(u.makespan_s),
            fmt_duration(u.queue_wait_p50_s),
            fmt_duration(u.queue_wait_p95_s),
            100.0 * u.fleet_share,
            100.0 * u.entitlement
        ));
    }
    if report.tenants.len() > MAX_ROWS {
        s.push_str(&format!(
            "… {} more tenants\n",
            report.tenants.len() - MAX_ROWS
        ));
    }
    let jobs: usize = report.tenants.iter().map(|u| u.jobs).sum();
    let completed: usize = report.tenants.iter().map(|u| u.completed).sum();
    s.push_str(&format!(
        "{:<14}{:>5}{:>8}{:>6}{:>6}{:>12.4}{:>11}\n",
        "TOTAL",
        "",
        "",
        jobs,
        completed,
        report.total_cost_dollars,
        fmt_duration(report.makespan_s)
    ));
    let violations = report
        .tenants
        .iter()
        .filter(|u| !u.budget_met || !u.deadline_met)
        .count();
    s.push_str(&format!(
        "aborted {}  ·  SLO violations {violations}\n",
        report.aborted
    ));
    if report.enforced {
        let stranded: usize = report.tenants.iter().map(|u| u.slo_aborted).sum();
        let escalated: usize = report.tenants.iter().map(|u| u.escalated).sum();
        s.push_str(&format!(
            "SLO enforcement: {stranded} stranded by budget, {escalated} escalated past deadline\n"
        ));
    }
    if let Some(o) = &report.outage {
        s.push_str(&format_outage(o));
    }
    s
}

/// Render a streaming run's steady-state telemetry (`medflow stream`;
/// DESIGN.md §17): ingest-to-processed latency percentiles, cost per
/// session, and the per-epoch backlog/re-plan table (capped at 20 rows
/// — year-long traces run hundreds of epochs).
pub fn format_stream(out: &crate::coordinator::stream::StreamOutcome) -> String {
    let r = &out.report;
    let mut s = format!(
        "stream co-simulation [{} arrivals, {} sessions, {} epochs]\n",
        r.pattern, r.sessions, r.epochs
    );
    s.push_str(&format!(
        "processed {}   aborted {}   stranded backlog {}   stream clock {}\n",
        r.processed,
        r.aborted,
        r.backlog_final,
        fmt_duration(r.stream_clock_s)
    ));
    s.push_str(&format!(
        "ingest→processed latency: p50 {}   p95 {}   mean {}\n",
        fmt_duration(r.latency_p50_s),
        fmt_duration(r.latency_p95_s),
        fmt_duration(r.latency_mean_s)
    ));
    s.push_str(&format!(
        "cost ${:.4} total   ${:.4}/session   backlog peak {}   escalations {}\n",
        r.total_cost_dollars, r.cost_per_session_dollars, r.backlog_peak, r.escalations
    ));
    s.push_str(&format!(
        "{:<7}{:>12}{:>10}{:>10}{:>9}{:>12}{:>12}{:>7}\n",
        "epoch", "plan at", "admitted", "done", "aborted", "makespan", "cost ($)", "esc"
    ));
    const MAX_ROWS: usize = 20;
    for e in out.epochs.iter().take(MAX_ROWS) {
        s.push_str(&format!(
            "{:<7}{:>12}{:>10}{:>10}{:>9}{:>12}{:>12.4}{:>7}\n",
            e.index,
            fmt_duration(e.t_plan_s),
            e.admitted,
            e.processed,
            e.aborted,
            fmt_duration(e.makespan_s),
            e.cost_dollars,
            if e.escalated { "yes" } else { "" }
        ));
    }
    if out.epochs.len() > MAX_ROWS {
        s.push_str(&format!("… {} more epochs\n", out.epochs.len() - MAX_ROWS));
    }
    if let Some(o) = &r.outage {
        s.push_str(&format_outage(o));
    }
    s
}

/// Render a cost-vs-makespan Pareto frontier (`medflow place
/// --frontier`; DESIGN.md §12) — the full curve Fig. 1 only showed two
/// points of. Points arrive pruned ([`crate::coordinator::placement::pareto`]):
/// cost strictly rises, makespan strictly falls.
pub fn format_frontier(points: &[crate::coordinator::placement::FrontierPoint]) -> String {
    let mut s =
        String::from("cost-vs-makespan frontier (Pareto set, dominated placements pruned)\n");
    s.push_str(&format!(
        "{:<24}{:>12}{:>14}   {}\n",
        "placement", "cost ($)", "makespan", "jobs per backend"
    ));
    for p in points {
        s.push_str(&format!(
            "{:<24}{:>12.4}{:>14}   {:?}\n",
            p.label,
            p.cost_dollars,
            fmt_duration(p.makespan_s),
            p.jobs_per_backend
        ));
    }
    s
}

/// Render aggregate transfer-scheduler telemetry (campaign reports and
/// `medflow transfer-sim`): link utilization, aggregate throughput,
/// concurrency, queueing.
pub fn format_transfer_stats(stats: &TransferStats) -> String {
    format!(
        "transfers {:>5}   bytes {:>10}   makespan {:>10}\n\
         peak streams {:>2}   link utilization {:>5.1}%   aggregate {:.3} Gb/s   mean queue wait {}\n",
        stats.transfers,
        crate::util::units::fmt_bytes(stats.bytes),
        crate::util::units::fmt_duration(stats.makespan_s),
        stats.peak_streams,
        stats.link_utilization * 100.0,
        stats.aggregate_gbps,
        crate::util::units::fmt_duration(stats.mean_queue_wait_s),
    )
}

/// Table 1 ground truth from the paper, used by tests/benches to check the
/// reproduction *shape* (who wins, by what factor).
pub mod paper {
    /// (throughput Gb/s, latency ms, $/hr, freesurfer mins, total $)
    pub const HPC: (f64, f64, f64, f64, f64) = (0.60, 0.16, 0.0096, 375.5, 0.36);
    pub const CLOUD: (f64, f64, f64, f64, f64) = (0.33, 19.56, 0.1856, 355.2, 6.59);
    pub const LOCAL: (f64, f64, f64, f64, f64) = (0.81, 1.64, 0.0913, 386.0, 3.53);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape_matches_paper_without_runtime() {
        let cols = table1(None, 42, 100, 100).unwrap();
        assert_eq!(cols.len(), 3);
        let hpc = &cols[0];
        let cloud = &cols[1];
        let local = &cols[2];
        // who wins on bandwidth: local > hpc > cloud
        assert!(local.throughput_gbps.0 > hpc.throughput_gbps.0);
        assert!(hpc.throughput_gbps.0 > cloud.throughput_gbps.0);
        // latency: cloud ≫ local > hpc
        assert!(cloud.latency_ms.0 > 10.0 * local.latency_ms.0);
        // cost: ~20x cloud/hpc
        let ratio = cloud.total_cost_dollars / hpc.total_cost_dollars;
        assert!((14.0..26.0).contains(&ratio), "ratio={ratio}");
        // absolute calibration within tolerance
        assert!((hpc.total_cost_dollars - paper::HPC.4).abs() < 0.08);
        assert!((cloud.total_cost_dollars - paper::CLOUD.4).abs() < 0.6);
        assert!((local.total_cost_dollars - paper::LOCAL.4).abs() < 0.4);
    }

    #[test]
    fn format_transfer_waits_reports_percentiles() {
        let rec = |id: u64, submit_s: f64, start_s: f64| TransferRecord {
            id,
            host: 0,
            bytes: 1_000,
            submit_s,
            start_s,
            end_s: start_s + 1.0,
            latency_s: 0.001,
            stream_gbps: 0.5,
        };
        let recs = [rec(0, 0.0, 0.0), rec(1, 0.0, 10.0), rec(2, 0.0, 20.0)];
        let s = format_transfer_waits(&recs);
        assert!(s.contains("p50 10.0 s"), "{s}");
        assert!(s.contains("p90") && s.contains("p99"), "{s}");
        assert!(format_transfer_waits(&[]).contains("p50"), "empty set renders");
    }

    #[test]
    fn format_fault_stats_reports_all_bands() {
        use crate::faults::{FaultCounts, FaultTelemetry};
        let t = FaultTelemetry {
            counts: FaultCounts {
                checksum: 1,
                pipeline: 8,
                node: 1,
                timeout: 2,
            },
            compute_retries: 9,
            transfer_retries: 1,
            restages: 2,
            aborted: 1,
            wasted_compute_minutes: 84.25,
            wasted_transfer_s: 12.5,
            expected_overrun_factor: 1.142,
            outage_kills: 3,
            outage_orphans: 5,
            outage_wasted_minutes: 7.5,
        };
        let s = format_fault_stats(&t);
        assert!(s.contains("12 failed attempts"), "{s}");
        assert!(s.contains("pipeline 8"), "{s}");
        assert!(s.contains("compute 9 (2 re-staged)"), "{s}");
        assert!(s.contains("aborted 1"), "{s}");
        assert!(s.contains("84.2 compute-min"), "{s}");
        assert!(s.contains("×1.142"), "{s}");
        assert!(s.contains("outages: 3 killed, 5 orphaned, 7.5 compute-min"), "{s}");
        // fault-free telemetry renders cleanly, with no outage band
        let clean = format_fault_stats(&FaultTelemetry::default());
        assert!(clean.contains("0 failed attempts"), "{clean}");
        assert!(clean.contains("×1.000"), "{clean}");
        assert!(!clean.contains("outages:"), "{clean}");
    }

    #[test]
    fn format_outage_reports_schedule_and_damage() {
        use crate::faults::outage::OutageStats;
        let s = format_outage(&OutageStats {
            windows: 4,
            brownouts: 2,
            killed: 3,
            orphaned: 6,
            re_placed: 5,
            killed_wasted_s: 90.0,
        });
        assert!(s.contains("4 outage windows"), "{s}");
        assert!(s.contains("2 brownouts"), "{s}");
        assert!(s.contains("killed 3"), "{s}");
        assert!(s.contains("orphaned 6 (5 re-placed)"), "{s}");
        assert!(s.contains("wasted 1m 30s"), "{s}");
    }

    #[test]
    fn format_table1_contains_all_rows() {
        let cols = table1(None, 1, 10, 10).unwrap();
        let text = format_table1(&cols);
        for needle in ["throughput", "Latency", "Cost per hr", "Freesurfer", "Total overhead"] {
            assert!(text.contains(needle), "{needle}\n{text}");
        }
    }

    #[test]
    fn table2_text_matches_capability_model() {
        let t = format_table2();
        assert!(t.contains("Singularity"));
        assert!(t.contains("Kubernetes"));
        assert!(t.contains("OS permissions required"));
        // singularity column: first Yes/No after the row label is "No"
        let row = t.lines().find(|l| l.starts_with("OS permissions")).unwrap();
        assert!(row.contains("No"));
    }

    #[test]
    fn table3_text_lists_all_solutions() {
        let t = format_table3();
        for s in ["XNAT", "COINS", "LORIS", "NITRC-IR", "OpenNeuro", "LONI IDA", "Datalad", "CLI"] {
            assert!(t.contains(s), "{s}");
        }
    }

    #[test]
    fn transfer_report_renders_stats_and_records() {
        use crate::netsim::scheduler::TransferScheduler;
        let mut sim = TransferScheduler::for_env(Env::Hpc, 2, 1);
        for i in 0..3u64 {
            sim.submit_at(i, 0, 100_000_000, 0.0);
        }
        sim.run_to_completion();
        let recs = format_transfer_records(sim.records());
        assert!(recs.contains("observed Gb/s"), "{recs}");
        assert_eq!(recs.lines().count(), 4, "header + 3 streams:\n{recs}");
        let stats = format_transfer_stats(&sim.stats());
        assert!(stats.contains("link utilization"), "{stats}");
        assert!(stats.contains("peak streams  2"), "{stats}");
    }

    #[test]
    fn format_placement_sums_backend_rows() {
        use crate::coordinator::placement::BackendUsage;
        let usage = [
            BackendUsage {
                name: "hpc".into(),
                env: Env::Hpc,
                jobs: 10,
                completed: 9,
                compute_minutes: 900.5,
                cost_dollars: 1.5,
                failed_attempts: 2,
                aborted: 1,
            },
            BackendUsage {
                name: "cloud".into(),
                env: Env::Cloud,
                jobs: 4,
                completed: 4,
                compute_minutes: 350.0,
                cost_dollars: 4.25,
                failed_attempts: 0,
                aborted: 0,
            },
        ];
        let s = format_placement("deadline-aware ≤ 2h", &usage);
        assert!(s.contains("deadline-aware"), "{s}");
        assert!(s.contains("hpc") && s.contains("cloud"), "{s}");
        assert!(s.lines().last().unwrap().contains("TOTAL"), "{s}");
        assert!(s.contains("14"), "totals row sums jobs:\n{s}");
        assert!(s.contains("5.7500"), "totals row sums dollars:\n{s}");
    }

    #[test]
    fn format_tenancy_caps_rows_and_totals_all() {
        use crate::coordinator::placement::{BackendKind, BackendSpec};
        use crate::coordinator::tenancy::{run_tenants, synthetic_tenants, TenancyConfig};
        let fleet = vec![BackendSpec {
            name: "hpc".into(),
            env: Env::Hpc,
            kind: BackendKind::Lanes { workers: 4 },
            faults: None,
            transfer_streams: 4,
        }];
        let tenants = synthetic_tenants(20, 2, 5);
        let cfg = TenancyConfig {
            queue_depth: Some(8),
            ..Default::default()
        };
        let out = run_tenants(&tenants, &fleet, &cfg);
        let s = format_tenancy(&out.report);
        assert!(s.contains("tenancy co-simulation [20 tenants, depth 8]"), "{s}");
        assert!(s.contains("tenant-0000"), "{s}");
        // 20 tenants, 16-row cap: the remainder is summarized …
        assert!(s.contains("… 4 more tenants"), "{s}");
        assert!(!s.contains("tenant-0019"), "row 20 must be elided: {s}");
        // … but the TOTAL row folds all 40 jobs
        let total = s.lines().find(|l| l.starts_with("TOTAL")).unwrap();
        assert!(total.contains("40"), "{total}");
        assert!(s.contains("wait p95"), "{s}");
        assert!(s.contains("SLO violations 0"), "{s}");
    }

    #[test]
    fn format_tenancy_renders_enforcement_and_outage_bands() {
        use crate::coordinator::placement::{BackendKind, BackendSpec};
        use crate::coordinator::tenancy::{synthetic_tenants, TenancyConfig};
        use crate::coordinator::RunSpec;
        use crate::faults::outage::OutageSchedule;
        let fleet = vec![BackendSpec {
            name: "hpc".into(),
            env: Env::Hpc,
            kind: BackendKind::Lanes { workers: 4 },
            faults: None,
            transfer_streams: 4,
        }];
        let tenants = synthetic_tenants(3, 2, 5);
        let out = RunSpec::new()
            .outages(OutageSchedule::empty())
            .enforce_slos(true)
            .run_tenants(&tenants, &fleet, &TenancyConfig::default());
        let s = format_tenancy(&out.report);
        assert!(s.contains("SLO enforcement: 0 stranded"), "{s}");
        assert!(s.contains("chaos: 0 outage windows, 0 brownouts"), "{s}");
    }

    #[test]
    fn format_frontier_lists_points_in_order() {
        use crate::coordinator::placement::FrontierPoint;
        let points = [
            FrontierPoint {
                label: "all-hpc".into(),
                cost_dollars: 0.5,
                makespan_s: 7200.0,
                jobs_per_backend: vec![12, 0, 0],
            },
            FrontierPoint {
                label: "deadline 1h".into(),
                cost_dollars: 2.0,
                makespan_s: 3600.0,
                jobs_per_backend: vec![8, 4, 0],
            },
        ];
        let s = format_frontier(&points);
        assert!(s.contains("Pareto"), "{s}");
        assert!(s.contains("all-hpc") && s.contains("deadline 1h"), "{s}");
        assert!(s.contains("[12, 0, 0]"), "{s}");
        assert_eq!(s.lines().count(), 4, "{s}");
    }

    #[test]
    fn fig1_adaptive_dominates() {
        let pts = fig1(42);
        let adaptive = pts.iter().find(|p| p.option.contains("Adaptive")).unwrap();
        let cloud = pts.iter().find(|p| p.option == "Cloud").unwrap();
        let local = pts.iter().find(|p| p.option.contains("Local")).unwrap();
        // the paper's Fig 1 claim: adaptive has high efficiency + bandwidth
        // with low cost + complexity
        assert!(adaptive.compute_efficiency > local.compute_efficiency);
        assert!(adaptive.cost < cloud.cost);
        assert!(adaptive.complexity < cloud.complexity);
        let csv = fig1_csv(&pts);
        assert_eq!(csv.lines().count(), 4);
    }
}
