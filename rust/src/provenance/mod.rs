//! Provenance records (paper §2.3): every pipeline run emits a config file
//! recording when it ran, who ran it, the container image, and the exact
//! input paths — enabling file provenance for downstream users.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{Json, JsonObj};

/// Provenance of one pipeline execution.
#[derive(Debug, Clone, PartialEq)]
pub struct Provenance {
    pub pipeline: String,
    pub container_image: String,
    pub container_sha: String,
    pub user: String,
    /// Seconds since epoch (simulation clock or wall clock).
    pub timestamp: f64,
    pub inputs: Vec<PathBuf>,
    pub compute_env: String,
    pub job_id: Option<u64>,
}

impl Provenance {
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.set("Pipeline", Json::str(&self.pipeline));
        o.set("ContainerImage", Json::str(&self.container_image));
        o.set("ContainerSha256", Json::str(&self.container_sha));
        o.set("User", Json::str(&self.user));
        o.set("Timestamp", Json::num(self.timestamp));
        o.set(
            "Inputs",
            Json::Arr(
                self.inputs
                    .iter()
                    .map(|p| Json::str(p.to_string_lossy()))
                    .collect(),
            ),
        );
        o.set("ComputeEnvironment", Json::str(&self.compute_env));
        if let Some(id) = self.job_id {
            o.set("JobId", Json::num(id as f64));
        }
        Json::Obj(o)
    }

    pub fn from_json(json: &Json) -> Result<Self> {
        let get_str = |key: &str| -> Result<String> {
            json.get_path(key)
                .and_then(Json::as_str)
                .map(String::from)
                .with_context(|| format!("provenance missing '{key}'"))
        };
        Ok(Self {
            pipeline: get_str("Pipeline")?,
            container_image: get_str("ContainerImage")?,
            container_sha: get_str("ContainerSha256")?,
            user: get_str("User")?,
            timestamp: json
                .get_path("Timestamp")
                .and_then(Json::as_f64)
                .context("provenance missing 'Timestamp'")?,
            inputs: json
                .get_path("Inputs")
                .and_then(Json::as_arr)
                .map(|arr| {
                    arr.iter()
                        .filter_map(Json::as_str)
                        .map(PathBuf::from)
                        .collect()
                })
                .unwrap_or_default(),
            compute_env: get_str("ComputeEnvironment")?,
            job_id: json.get_path("JobId").and_then(Json::as_i64).map(|v| v as u64),
        })
    }

    /// Write `provenance.json` into an output directory.
    pub fn save(&self, out_dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(out_dir)?;
        let path = out_dir.join("provenance.json");
        std::fs::write(&path, self.to_json().to_string_pretty())?;
        Ok(path)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Provenance {
        Provenance {
            pipeline: "freesurfer".into(),
            container_image: "freesurfer_7.2.0.sif".into(),
            container_sha: "ab".repeat(32),
            user: "mkim".into(),
            timestamp: 1_720_000_000.0,
            inputs: vec![PathBuf::from("/store/DS/sub-01/anat/sub-01_T1w.nii.gz")],
            compute_env: "hpc".into(),
            job_id: Some(12345),
        }
    }

    #[test]
    fn json_roundtrip() {
        let p = sample();
        assert_eq!(Provenance::from_json(&p.to_json()).unwrap(), p);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("medflow_prov_{}", std::process::id()));
        let p = sample();
        let path = p.save(&dir).unwrap();
        assert_eq!(Provenance::load(&path).unwrap(), p);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_fields_rejected() {
        let j = Json::parse(r#"{"Pipeline": "x"}"#).unwrap();
        assert!(Provenance::from_json(&j).is_err());
    }

    #[test]
    fn job_id_optional() {
        let mut p = sample();
        p.job_id = None;
        assert_eq!(Provenance::from_json(&p.to_json()).unwrap().job_id, None);
    }
}
