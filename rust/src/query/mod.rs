//! Automated archive query (paper §2.3): given a dataset and a pipeline,
//! find every scanning session that (a) satisfies the pipeline's input
//! criteria and (b) has not already been processed — and explain, per
//! skipped session, why it was skipped (the accompanying CSV).

use std::path::PathBuf;

use anyhow::Result;

use crate::bids::{BidsDataset, BidsName, Modality};
use crate::pipeline::{InputReq, PipelineSpec};
use crate::util::csv::write_csv;

/// One runnable job instance discovered by the query.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub dataset: String,
    pub pipeline: String,
    pub subject: String,
    pub session: Option<String>,
    /// Input image paths (symlink targets resolved by the executor).
    pub inputs: Vec<PathBuf>,
    pub cores: u32,
    pub ram_gb: u32,
}

impl JobSpec {
    /// Stable instance id `dataset/sub[/ses]/pipeline`.
    pub fn instance_id(&self) -> String {
        match &self.session {
            Some(ses) => format!("{}/sub-{}/ses-{}/{}", self.dataset, self.subject, ses, self.pipeline),
            None => format!("{}/sub-{}/{}", self.dataset, self.subject, self.pipeline),
        }
    }
}

/// Why a session was not queued (the paper's example: "no available T1w
/// image in the scanning session").
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SkipReason {
    NoT1w,
    NoDwi,
    MissingPrior(&'static str),
    AlreadyProcessed,
}

impl SkipReason {
    pub fn as_str(&self) -> String {
        match self {
            SkipReason::NoT1w => "no available T1w image in session".into(),
            SkipReason::NoDwi => "no available DWI image in session".into(),
            SkipReason::MissingPrior(p) => format!("prerequisite pipeline '{p}' not yet run"),
            SkipReason::AlreadyProcessed => "already processed".into(),
        }
    }
}

/// One skipped session record.
#[derive(Debug, Clone, PartialEq)]
pub struct SkipRecord {
    pub subject: String,
    pub session: Option<String>,
    pub reason: SkipReason,
}

/// Query output: runnable jobs + skip records.
#[derive(Debug, Clone, Default)]
pub struct QueryResult {
    pub runnable: Vec<JobSpec>,
    pub skipped: Vec<SkipRecord>,
}

impl QueryResult {
    /// The paper's companion CSV: session, status, cause.
    pub fn skip_csv(&self) -> String {
        let rows = self
            .skipped
            .iter()
            .map(|s| {
                vec![
                    format!("sub-{}", s.subject),
                    s.session.clone().map(|x| format!("ses-{x}")).unwrap_or_default(),
                    s.reason.as_str(),
                ]
            })
            .collect::<Vec<_>>();
        write_csv(&["subject", "session", "skip_reason"], &rows)
    }
}

/// Run the query for one pipeline over one BIDS dataset.
pub fn find_runnable(ds: &BidsDataset, pipeline: &PipelineSpec) -> Result<QueryResult> {
    let mut result = QueryResult::default();
    for subject in ds.subjects()? {
        for session in ds.sessions(&subject)? {
            let ses = session.as_deref();
            let t1 = ds.raw_images(&BidsName::new(&subject, ses, Modality::T1w));
            let dwi = ds.raw_images(&BidsName::new(&subject, ses, Modality::Dwi));
            let probe = BidsName::new(&subject, ses, Modality::T1w);

            // 1. already processed? (idempotency: never re-queue)
            if ds.has_derivative(pipeline.name, &probe) {
                result.skipped.push(SkipRecord {
                    subject: subject.clone(),
                    session: session.clone(),
                    reason: SkipReason::AlreadyProcessed,
                });
                continue;
            }

            // 2. input criteria
            let (inputs, missing): (Vec<PathBuf>, Option<SkipReason>) = match &pipeline.input {
                InputReq::T1w => (t1.clone(), t1.is_empty().then_some(SkipReason::NoT1w)),
                InputReq::Dwi => (dwi.clone(), dwi.is_empty().then_some(SkipReason::NoDwi)),
                InputReq::T1wAndDwi => {
                    let mut v = t1.clone();
                    v.extend(dwi.clone());
                    let miss = if t1.is_empty() {
                        Some(SkipReason::NoT1w)
                    } else if dwi.is_empty() {
                        Some(SkipReason::NoDwi)
                    } else {
                        None
                    };
                    (v, miss)
                }
                InputReq::T1wAndPrior(dep) => {
                    let miss = if t1.is_empty() {
                        Some(SkipReason::NoT1w)
                    } else if !ds.has_derivative(dep, &probe) {
                        Some(SkipReason::MissingPrior(dep))
                    } else {
                        None
                    };
                    (t1.clone(), miss)
                }
                InputReq::DwiAndPrior(dep) => {
                    let miss = if dwi.is_empty() {
                        Some(SkipReason::NoDwi)
                    } else if !ds.has_derivative(dep, &probe) {
                        Some(SkipReason::MissingPrior(dep))
                    } else {
                        None
                    };
                    (dwi.clone(), miss)
                }
            };

            match missing {
                Some(reason) => result.skipped.push(SkipRecord {
                    subject: subject.clone(),
                    session: session.clone(),
                    reason,
                }),
                None => result.runnable.push(JobSpec {
                    dataset: ds.name.clone(),
                    pipeline: pipeline.name.to_string(),
                    subject: subject.clone(),
                    session: session.clone(),
                    inputs,
                    cores: pipeline.resources.cores,
                    ram_gb: pipeline.resources.ram_gb,
                }),
            }
        }
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::by_name;
    use std::path::Path;

    fn tmpds(tag: &str) -> BidsDataset {
        let parent = std::env::temp_dir().join(format!("medflow_query_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&parent).unwrap();
        BidsDataset::create(&parent, "DS").unwrap()
    }

    fn add_image(ds: &BidsDataset, sub: &str, ses: Option<&str>, m: Modality) {
        let name = BidsName::new(sub, ses, m);
        let p = ds.raw_path(&name, "nii.gz");
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(&p, b"img").unwrap();
    }

    fn mark_done(ds: &BidsDataset, pipeline: &str, sub: &str, ses: Option<&str>) {
        let name = BidsName::new(sub, ses, Modality::T1w);
        let d = ds.derivative_dir(pipeline, &name);
        std::fs::create_dir_all(&d).unwrap();
        std::fs::write(d.join("out.txt"), b"done").unwrap();
    }

    fn cleanup(ds: &BidsDataset) {
        std::fs::remove_dir_all(ds.root.parent().unwrap()).unwrap();
    }

    #[test]
    fn finds_unprocessed_t1_sessions() {
        let ds = tmpds("t1");
        add_image(&ds, "01", Some("a"), Modality::T1w);
        add_image(&ds, "02", Some("a"), Modality::Dwi); // no T1 → skip
        let fs = by_name("freesurfer").unwrap();
        let r = find_runnable(&ds, &fs).unwrap();
        assert_eq!(r.runnable.len(), 1);
        assert_eq!(r.runnable[0].subject, "01");
        assert_eq!(r.skipped.len(), 1);
        assert_eq!(r.skipped[0].reason, SkipReason::NoT1w);
        cleanup(&ds);
    }

    #[test]
    fn already_processed_not_requeued() {
        let ds = tmpds("done");
        add_image(&ds, "01", None, Modality::T1w);
        mark_done(&ds, "freesurfer", "01", None);
        let fs = by_name("freesurfer").unwrap();
        let r = find_runnable(&ds, &fs).unwrap();
        assert!(r.runnable.is_empty());
        assert_eq!(r.skipped[0].reason, SkipReason::AlreadyProcessed);
        cleanup(&ds);
    }

    #[test]
    fn prior_pipeline_gates_dependents() {
        let ds = tmpds("prior");
        add_image(&ds, "01", None, Modality::Dwi);
        let ts = by_name("tractseg").unwrap(); // needs prequal first
        let r = find_runnable(&ds, &ts).unwrap();
        assert!(r.runnable.is_empty());
        assert_eq!(r.skipped[0].reason, SkipReason::MissingPrior("prequal"));
        mark_done(&ds, "prequal", "01", None);
        let r2 = find_runnable(&ds, &ts).unwrap();
        assert_eq!(r2.runnable.len(), 1);
        cleanup(&ds);
    }

    #[test]
    fn multimodal_requires_both() {
        let ds = tmpds("both");
        add_image(&ds, "01", None, Modality::T1w);
        add_image(&ds, "02", None, Modality::T1w);
        add_image(&ds, "02", None, Modality::Dwi);
        let cs = by_name("connectome_special").unwrap();
        let r = find_runnable(&ds, &cs).unwrap();
        assert_eq!(r.runnable.len(), 1);
        assert_eq!(r.runnable[0].subject, "02");
        assert_eq!(r.runnable[0].inputs.len(), 2);
        assert_eq!(r.skipped[0].reason, SkipReason::NoDwi);
        cleanup(&ds);
    }

    #[test]
    fn skip_csv_lists_causes() {
        let ds = tmpds("csv");
        add_image(&ds, "01", Some("x"), Modality::Dwi);
        let fs = by_name("freesurfer").unwrap();
        let r = find_runnable(&ds, &fs).unwrap();
        let csv = r.skip_csv();
        assert!(csv.contains("subject,session,skip_reason"));
        assert!(csv.contains("sub-01,ses-x,no available T1w image in session"));
        cleanup(&ds);
    }

    #[test]
    fn instance_ids_stable() {
        let j = JobSpec {
            dataset: "DS".into(),
            pipeline: "freesurfer".into(),
            subject: "01".into(),
            session: Some("a".into()),
            inputs: vec![],
            cores: 1,
            ram_gb: 8,
        };
        assert_eq!(j.instance_id(), "DS/sub-01/ses-a/freesurfer");
    }

    #[test]
    fn rerun_after_completion_is_idempotent() {
        let ds = tmpds("idem");
        add_image(&ds, "01", None, Modality::T1w);
        let fs = by_name("freesurfer").unwrap();
        let r1 = find_runnable(&ds, &fs).unwrap();
        assert_eq!(r1.runnable.len(), 1);
        mark_done(&ds, "freesurfer", "01", None);
        let r2 = find_runnable(&ds, &fs).unwrap();
        assert!(r2.runnable.is_empty());
        cleanup(&ds);
    }

    #[test]
    fn empty_dataset_yields_nothing() {
        let ds = tmpds("empty");
        let fs = by_name("freesurfer").unwrap();
        let r = find_runnable(&ds, &fs).unwrap();
        assert!(r.runnable.is_empty() && r.skipped.is_empty());
        // keep Path import used
        let _ = Path::new(".");
        cleanup(&ds);
    }
}
