//! Automated archive query (paper §2.3): given a dataset and a pipeline,
//! find every scanning session that (a) satisfies the pipeline's input
//! criteria and (b) has not already been processed — and explain, per
//! skipped session, why it was skipped (the accompanying CSV).
//!
//! Three query paths, one semantics:
//!
//! * [`find_runnable`] — the baseline full filesystem scan (O(all
//!   sessions) `read_dir` calls; fine for MASiVar-sized datasets).
//! * [`find_runnable_sharded`] — parallel scan over the persistent
//!   [`EntityIndex`](crate::archive::EntityIndex) shards; no per-session
//!   filesystem traffic for input criteria.
//! * [`incremental::IncrementalEngine`] — the campaign path: replays
//!   cached verdicts and evaluates only new, changed, or newly unblocked
//!   sessions (O(changes); see DESIGN.md §6).

pub mod incremental;

pub use incremental::{DeltaLedger, IncrementalEngine};

use std::path::PathBuf;

use anyhow::Result;

use crate::archive::{EntityIndex, ProcessedIndex, SessionKey};
use crate::bids::{BidsDataset, BidsName, Modality};
use crate::pipeline::{InputReq, PipelineSpec};
use crate::util::csv::write_csv;
use crate::util::pool::run_parallel;

/// One runnable job instance discovered by the query.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub dataset: String,
    pub pipeline: String,
    pub subject: String,
    pub session: Option<String>,
    /// Input image paths (symlink targets resolved by the executor).
    pub inputs: Vec<PathBuf>,
    pub cores: u32,
    pub ram_gb: u32,
}

impl JobSpec {
    /// Stable instance id `dataset/sub[/ses]/pipeline`.
    pub fn instance_id(&self) -> String {
        match &self.session {
            Some(ses) => {
                format!("{}/sub-{}/ses-{}/{}", self.dataset, self.subject, ses, self.pipeline)
            }
            None => format!("{}/sub-{}/{}", self.dataset, self.subject, self.pipeline),
        }
    }
}

/// Why a session was not queued (the paper's example: "no available T1w
/// image in the scanning session").
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SkipReason {
    NoT1w,
    NoDwi,
    MissingPrior(&'static str),
    AlreadyProcessed,
}

impl SkipReason {
    pub fn as_str(&self) -> String {
        match self {
            SkipReason::NoT1w => "no available T1w image in session".into(),
            SkipReason::NoDwi => "no available DWI image in session".into(),
            SkipReason::MissingPrior(p) => format!("prerequisite pipeline '{p}' not yet run"),
            SkipReason::AlreadyProcessed => "already processed".into(),
        }
    }
}

/// One skipped session record.
#[derive(Debug, Clone, PartialEq)]
pub struct SkipRecord {
    pub subject: String,
    pub session: Option<String>,
    pub reason: SkipReason,
}

/// Query output: runnable jobs + skip records.
#[derive(Debug, Clone, Default)]
pub struct QueryResult {
    pub runnable: Vec<JobSpec>,
    pub skipped: Vec<SkipRecord>,
}

impl QueryResult {
    /// The paper's companion CSV: session, status, cause.
    pub fn skip_csv(&self) -> String {
        let rows = self
            .skipped
            .iter()
            .map(|s| {
                vec![
                    format!("sub-{}", s.subject),
                    s.session.clone().map(|x| format!("ses-{x}")).unwrap_or_default(),
                    s.reason.as_str(),
                ]
            })
            .collect::<Vec<_>>();
        write_csv(&["subject", "session", "skip_reason"], &rows)
    }
}

/// Outcome of applying a pipeline's input criteria to one session.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum Evaluation {
    /// Runnable with these staged input paths.
    Runnable(Vec<PathBuf>),
    Skip(SkipReason),
}

/// Apply `pipeline`'s input criteria to one session's image inventory.
/// `has_prior(dep)` answers whether the prerequisite pipeline has already
/// completed this session. Shared by every query path so the three scans
/// cannot drift semantically.
pub(crate) fn evaluate_inputs(
    pipeline: &PipelineSpec,
    t1: &[PathBuf],
    dwi: &[PathBuf],
    has_prior: impl Fn(&'static str) -> bool,
) -> Evaluation {
    let (inputs, missing): (Vec<PathBuf>, Option<SkipReason>) = match pipeline.input.clone() {
        InputReq::T1w => (t1.to_vec(), t1.is_empty().then_some(SkipReason::NoT1w)),
        InputReq::Dwi => (dwi.to_vec(), dwi.is_empty().then_some(SkipReason::NoDwi)),
        InputReq::T1wAndDwi => {
            let mut v = t1.to_vec();
            v.extend(dwi.iter().cloned());
            let miss = if t1.is_empty() {
                Some(SkipReason::NoT1w)
            } else if dwi.is_empty() {
                Some(SkipReason::NoDwi)
            } else {
                None
            };
            (v, miss)
        }
        InputReq::T1wAndPrior(dep) => {
            let miss = if t1.is_empty() {
                Some(SkipReason::NoT1w)
            } else if !has_prior(dep) {
                Some(SkipReason::MissingPrior(dep))
            } else {
                None
            };
            (t1.to_vec(), miss)
        }
        InputReq::DwiAndPrior(dep) => {
            let miss = if dwi.is_empty() {
                Some(SkipReason::NoDwi)
            } else if !has_prior(dep) {
                Some(SkipReason::MissingPrior(dep))
            } else {
                None
            };
            (dwi.to_vec(), miss)
        }
    };
    match missing {
        Some(reason) => Evaluation::Skip(reason),
        None => Evaluation::Runnable(inputs),
    }
}

/// Verdict for one indexed session — the shared core of the sharded and
/// incremental scan paths, so their semantics and accounting cannot drift.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum SessionVerdict {
    /// Already done; `from_index` tells whether the processed-set answered
    /// (no filesystem traffic) or a `derivatives/` probe did (the caller
    /// should absorb the session into the processed set).
    AlreadyProcessed { from_index: bool },
    Skip(SkipReason),
    Runnable(Vec<PathBuf>),
}

/// Judge one session from its index record: processed-set lookup →
/// `derivatives/` probe → input criteria (with prior-pipeline checks
/// against the processed set, falling back to a probe).
pub(crate) fn evaluate_session(
    ds: &BidsDataset,
    pipeline: &PipelineSpec,
    key: &SessionKey,
    rec: &crate::archive::SessionRecord,
    processed: &ProcessedIndex,
) -> SessionVerdict {
    let probe = BidsName::new(&key.subject, key.session.as_deref(), Modality::T1w);
    if processed.contains(pipeline.name, key) {
        return SessionVerdict::AlreadyProcessed { from_index: true };
    }
    if ds.has_derivative(pipeline.name, &probe) {
        return SessionVerdict::AlreadyProcessed { from_index: false };
    }
    let t1 = rec.resolved(ds, Modality::T1w);
    let dwi = rec.resolved(ds, Modality::Dwi);
    match evaluate_inputs(pipeline, &t1, &dwi, |dep| {
        processed.contains(dep, key) || ds.has_derivative(dep, &probe)
    }) {
        Evaluation::Skip(reason) => SessionVerdict::Skip(reason),
        Evaluation::Runnable(inputs) => SessionVerdict::Runnable(inputs),
    }
}

/// Build the [`JobSpec`] for a session judged runnable.
pub(crate) fn job_for(
    ds: &BidsDataset,
    pipeline: &PipelineSpec,
    key: &SessionKey,
    inputs: Vec<PathBuf>,
) -> JobSpec {
    JobSpec {
        dataset: ds.name.clone(),
        pipeline: pipeline.name.to_string(),
        subject: key.subject.clone(),
        session: key.session.clone(),
        inputs,
        cores: pipeline.resources.cores,
        ram_gb: pipeline.resources.ram_gb,
    }
}

/// Run the query for one pipeline over one BIDS dataset — the baseline
/// full filesystem scan (every subject, session and modality directory is
/// walked on every call).
pub fn find_runnable(ds: &BidsDataset, pipeline: &PipelineSpec) -> Result<QueryResult> {
    let mut result = QueryResult::default();
    for subject in ds.subjects()? {
        for session in ds.sessions(&subject)? {
            let ses = session.as_deref();
            let t1 = ds.raw_images(&BidsName::new(&subject, ses, Modality::T1w));
            let dwi = ds.raw_images(&BidsName::new(&subject, ses, Modality::Dwi));
            let probe = BidsName::new(&subject, ses, Modality::T1w);

            // 1. already processed? (idempotency: never re-queue)
            if ds.has_derivative(pipeline.name, &probe) {
                result.skipped.push(SkipRecord {
                    subject: subject.clone(),
                    session: session.clone(),
                    reason: SkipReason::AlreadyProcessed,
                });
                continue;
            }

            // 2. input criteria
            let key = SessionKey::new(&subject, ses);
            match evaluate_inputs(pipeline, &t1, &dwi, |dep| ds.has_derivative(dep, &probe)) {
                Evaluation::Skip(reason) => result.skipped.push(SkipRecord {
                    subject: subject.clone(),
                    session: session.clone(),
                    reason,
                }),
                Evaluation::Runnable(inputs) => {
                    result.runnable.push(job_for(ds, pipeline, &key, inputs))
                }
            }
        }
    }
    Ok(result)
}

/// Telemetry from an indexed or incremental query — how much work the
/// engine actually did, and how much it answered from persistent state.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueryStats {
    /// `true` when the whole dataset tree was walked (the baseline path).
    pub full_scan: bool,
    /// Index shards visited.
    pub shards_scanned: usize,
    /// Sessions whose criteria were (re)evaluated this run.
    pub sessions_examined: usize,
    /// Sessions answered from the processed-set or skip cache (no
    /// evaluation, no filesystem traffic).
    pub sessions_replayed: usize,
    /// Newly acquired sessions discovered by the refresh pass.
    pub new_sessions: usize,
}

/// Sort a query result into the canonical (subject, session) order so
/// every query path reports identically regardless of shard layout.
pub(crate) fn canonicalize(result: &mut QueryResult) {
    result
        .runnable
        .sort_by(|a, b| (&a.subject, &a.session).cmp(&(&b.subject, &b.session)));
    result
        .skipped
        .sort_by(|a, b| (&a.subject, &a.session).cmp(&(&b.subject, &b.session)));
}

/// Parallel shard-scan query over the persistent entity index: input
/// criteria come from [`SessionRecord`](crate::archive::SessionRecord)s
/// (no per-session filesystem walks); the already-processed check consults
/// the [`ProcessedIndex`] first and falls back to a `derivatives/` probe
/// only for sessions the index does not yet know about. Shards are scanned
/// across `workers` threads via [`run_parallel`].
pub fn find_runnable_sharded(
    ds: &BidsDataset,
    pipeline: &PipelineSpec,
    index: &EntityIndex,
    processed: &ProcessedIndex,
    workers: usize,
) -> Result<(QueryResult, QueryStats)> {
    let shard_jobs: Vec<_> = (0..index.n_shards())
        .filter(|&i| !index.shard(i).is_empty())
        .map(|i| {
            move || {
                let mut runnable = Vec::new();
                let mut skipped = Vec::new();
                let mut examined = 0usize;
                let mut replayed = 0usize;
                for (key, rec) in index.shard(i) {
                    let record = |reason: SkipReason| SkipRecord {
                        subject: key.subject.clone(),
                        session: key.session.clone(),
                        reason,
                    };
                    match evaluate_session(ds, pipeline, key, rec, processed) {
                        // processed-set hit: answered from the index
                        // (replayed); a derivatives/ probe hit still cost
                        // filesystem work (examined) — same accounting as
                        // the incremental path
                        SessionVerdict::AlreadyProcessed { from_index } => {
                            if from_index {
                                replayed += 1;
                            } else {
                                examined += 1;
                            }
                            skipped.push(record(SkipReason::AlreadyProcessed));
                        }
                        SessionVerdict::Skip(reason) => {
                            examined += 1;
                            skipped.push(record(reason));
                        }
                        SessionVerdict::Runnable(inputs) => {
                            examined += 1;
                            runnable.push(job_for(ds, pipeline, key, inputs));
                        }
                    }
                }
                (runnable, skipped, examined, replayed)
            }
        })
        .collect();

    let shards_scanned = shard_jobs.len();
    let mut result = QueryResult::default();
    let mut stats = QueryStats {
        shards_scanned,
        ..QueryStats::default()
    };
    for (runnable, skipped, examined, replayed) in run_parallel(workers.max(1), shard_jobs) {
        result.runnable.extend(runnable);
        result.skipped.extend(skipped);
        stats.sessions_examined += examined;
        stats.sessions_replayed += replayed;
    }
    canonicalize(&mut result);
    Ok((result, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::by_name;
    use std::path::Path;

    fn tmpds(tag: &str) -> BidsDataset {
        let parent =
            std::env::temp_dir().join(format!("medflow_query_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&parent).unwrap();
        BidsDataset::create(&parent, "DS").unwrap()
    }

    fn add_image(ds: &BidsDataset, sub: &str, ses: Option<&str>, m: Modality) {
        let name = BidsName::new(sub, ses, m);
        let p = ds.raw_path(&name, "nii.gz");
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(&p, b"img").unwrap();
    }

    fn mark_done(ds: &BidsDataset, pipeline: &str, sub: &str, ses: Option<&str>) {
        let name = BidsName::new(sub, ses, Modality::T1w);
        let d = ds.derivative_dir(pipeline, &name);
        std::fs::create_dir_all(&d).unwrap();
        std::fs::write(d.join("out.txt"), b"done").unwrap();
    }

    fn cleanup(ds: &BidsDataset) {
        std::fs::remove_dir_all(ds.root.parent().unwrap()).unwrap();
    }

    #[test]
    fn finds_unprocessed_t1_sessions() {
        let ds = tmpds("t1");
        add_image(&ds, "01", Some("a"), Modality::T1w);
        add_image(&ds, "02", Some("a"), Modality::Dwi); // no T1 → skip
        let fs = by_name("freesurfer").unwrap();
        let r = find_runnable(&ds, &fs).unwrap();
        assert_eq!(r.runnable.len(), 1);
        assert_eq!(r.runnable[0].subject, "01");
        assert_eq!(r.skipped.len(), 1);
        assert_eq!(r.skipped[0].reason, SkipReason::NoT1w);
        cleanup(&ds);
    }

    #[test]
    fn already_processed_not_requeued() {
        let ds = tmpds("done");
        add_image(&ds, "01", None, Modality::T1w);
        mark_done(&ds, "freesurfer", "01", None);
        let fs = by_name("freesurfer").unwrap();
        let r = find_runnable(&ds, &fs).unwrap();
        assert!(r.runnable.is_empty());
        assert_eq!(r.skipped[0].reason, SkipReason::AlreadyProcessed);
        cleanup(&ds);
    }

    #[test]
    fn prior_pipeline_gates_dependents() {
        let ds = tmpds("prior");
        add_image(&ds, "01", None, Modality::Dwi);
        let ts = by_name("tractseg").unwrap(); // needs prequal first
        let r = find_runnable(&ds, &ts).unwrap();
        assert!(r.runnable.is_empty());
        assert_eq!(r.skipped[0].reason, SkipReason::MissingPrior("prequal"));
        mark_done(&ds, "prequal", "01", None);
        let r2 = find_runnable(&ds, &ts).unwrap();
        assert_eq!(r2.runnable.len(), 1);
        cleanup(&ds);
    }

    #[test]
    fn multimodal_requires_both() {
        let ds = tmpds("both");
        add_image(&ds, "01", None, Modality::T1w);
        add_image(&ds, "02", None, Modality::T1w);
        add_image(&ds, "02", None, Modality::Dwi);
        let cs = by_name("connectome_special").unwrap();
        let r = find_runnable(&ds, &cs).unwrap();
        assert_eq!(r.runnable.len(), 1);
        assert_eq!(r.runnable[0].subject, "02");
        assert_eq!(r.runnable[0].inputs.len(), 2);
        assert_eq!(r.skipped[0].reason, SkipReason::NoDwi);
        cleanup(&ds);
    }

    #[test]
    fn skip_csv_lists_causes() {
        let ds = tmpds("csv");
        add_image(&ds, "01", Some("x"), Modality::Dwi);
        let fs = by_name("freesurfer").unwrap();
        let r = find_runnable(&ds, &fs).unwrap();
        let csv = r.skip_csv();
        assert!(csv.contains("subject,session,skip_reason"));
        assert!(csv.contains("sub-01,ses-x,no available T1w image in session"));
        cleanup(&ds);
    }

    #[test]
    fn instance_ids_stable() {
        let j = JobSpec {
            dataset: "DS".into(),
            pipeline: "freesurfer".into(),
            subject: "01".into(),
            session: Some("a".into()),
            inputs: vec![],
            cores: 1,
            ram_gb: 8,
        };
        assert_eq!(j.instance_id(), "DS/sub-01/ses-a/freesurfer");
    }

    #[test]
    fn rerun_after_completion_is_idempotent() {
        let ds = tmpds("idem");
        add_image(&ds, "01", None, Modality::T1w);
        let fs = by_name("freesurfer").unwrap();
        let r1 = find_runnable(&ds, &fs).unwrap();
        assert_eq!(r1.runnable.len(), 1);
        mark_done(&ds, "freesurfer", "01", None);
        let r2 = find_runnable(&ds, &fs).unwrap();
        assert!(r2.runnable.is_empty());
        cleanup(&ds);
    }

    #[test]
    fn empty_dataset_yields_nothing() {
        let ds = tmpds("empty");
        let fs = by_name("freesurfer").unwrap();
        let r = find_runnable(&ds, &fs).unwrap();
        assert!(r.runnable.is_empty() && r.skipped.is_empty());
        // keep Path import used
        let _ = Path::new(".");
        cleanup(&ds);
    }
}
