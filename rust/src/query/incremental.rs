//! Incremental query engine — O(changes) discovery of runnable sessions.
//!
//! The engine layers three pieces of persistent state (all under the
//! dataset's [`index_dir`](crate::bids::BidsDataset::index_dir)):
//!
//! 1. the sharded [`EntityIndex`] (what sessions exist, which images each
//!    holds),
//! 2. the [`ProcessedIndex`] (what each pipeline already completed, with a
//!    per-pipeline version counter), and
//! 3. a per-pipeline *skip cache* (why a session was last skipped, stamped
//!    with the session's index generation and — for
//!    [`SkipReason::MissingPrior`] — the prerequisite's processed-set
//!    version).
//!
//! A query then touches only the delta:
//!
//! * sessions in the processed set replay as
//!   [`SkipReason::AlreadyProcessed`] without filesystem traffic;
//! * cached structural skips (`NoT1w`/`NoDwi`) replay while the session's
//!   record generation is unchanged;
//! * cached `MissingPrior` skips replay while the prerequisite pipeline's
//!   version is unchanged — when the prerequisite completes new sessions
//!   (version bump), exactly the blocked sessions are re-evaluated and
//!   unblock;
//! * everything else — newly acquired sessions found by the refresh pass,
//!   changed sessions, never-seen sessions — is evaluated in parallel
//!   across index shards.
//!
//! Completions must flow back through [`IncrementalEngine::record_completion`]
//! (the coordinator does this per finished job). Derivatives written behind
//! the engine's back are still detected for never-cached sessions via a
//! `derivatives/` probe, but cached verdicts are only invalidated by
//! generation/version changes — after out-of-band writes, call
//! [`IncrementalEngine::invalidate_pipeline`].

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::archive::{EntityIndex, ProcessedIndex, SessionKey, DEFAULT_SHARDS};
use crate::bids::BidsDataset;
use crate::pipeline::{by_name, PipelineSpec};
use crate::util::json::{Json, JsonObj};
use crate::util::pool::run_parallel;

use super::{
    canonicalize, evaluate_session, job_for, QueryResult, QueryStats, SessionVerdict, SkipReason,
    SkipRecord,
};

/// A cached skip verdict for one (pipeline, session).
#[derive(Debug, Clone, PartialEq, Eq)]
struct CachedSkip {
    reason: CachedReason,
    /// [`SessionRecord`](crate::archive::SessionRecord) generation the
    /// verdict was computed against.
    generation: u64,
    /// For `MissingPrior`: the prerequisite's processed-set version at
    /// evaluation time. 0 otherwise.
    dep_version: u64,
}

#[derive(Debug, Clone, PartialEq, Eq)]
enum CachedReason {
    NoT1w,
    NoDwi,
    MissingPrior(String),
}

impl CachedSkip {
    fn from_reason(
        reason: &SkipReason,
        generation: u64,
        processed: &ProcessedIndex,
    ) -> Option<Self> {
        let (reason, dep_version) = match reason {
            SkipReason::NoT1w => (CachedReason::NoT1w, 0),
            SkipReason::NoDwi => (CachedReason::NoDwi, 0),
            SkipReason::MissingPrior(dep) => {
                (CachedReason::MissingPrior(dep.to_string()), processed.version(dep))
            }
            // AlreadyProcessed lives in the ProcessedIndex, not here.
            SkipReason::AlreadyProcessed => return None,
        };
        Some(Self {
            reason,
            generation,
            dep_version,
        })
    }

    /// Whether the verdict still holds for a record at `generation` given
    /// the current processed state.
    fn valid(&self, generation: u64, processed: &ProcessedIndex) -> bool {
        if self.generation != generation {
            return false;
        }
        match &self.reason {
            CachedReason::MissingPrior(dep) => self.dep_version == processed.version(dep),
            _ => true,
        }
    }

    /// Reconstruct the public [`SkipReason`]. `MissingPrior` names are
    /// resolved through the pipeline registry (the source of the `'static`
    /// strings); an unknown name yields `None` and forces re-evaluation.
    fn to_reason(&self) -> Option<SkipReason> {
        Some(match &self.reason {
            CachedReason::NoT1w => SkipReason::NoT1w,
            CachedReason::NoDwi => SkipReason::NoDwi,
            CachedReason::MissingPrior(dep) => SkipReason::MissingPrior(by_name(dep)?.name),
        })
    }

    fn kind(&self) -> &'static str {
        match self.reason {
            CachedReason::NoT1w => "NoT1w",
            CachedReason::NoDwi => "NoDwi",
            CachedReason::MissingPrior(_) => "MissingPrior",
        }
    }
}

/// The incremental query engine for one dataset. Open once per dataset,
/// query any number of pipelines, [`save`](Self::save) after mutations.
pub struct IncrementalEngine {
    pub index: EntityIndex,
    pub processed: ProcessedIndex,
    /// pipeline → session → cached verdict.
    skip_cache: BTreeMap<String, BTreeMap<SessionKey, CachedSkip>>,
    /// Entity-index generation last persisted — [`Self::save`] skips the
    /// (large) shard rewrite when nothing changed.
    saved_index_generation: u64,
}

impl IncrementalEngine {
    /// Load the dataset's persistent query state, building (and
    /// persisting) the entity index on first use.
    pub fn open(ds: &BidsDataset) -> Result<Self> {
        let index = EntityIndex::open_or_build(ds, DEFAULT_SHARDS)?;
        let saved_index_generation = index.generation;
        Ok(Self {
            index,
            processed: ProcessedIndex::open(ds)?,
            skip_cache: load_skip_cache(&skip_cache_path(ds))?,
            saved_index_generation,
        })
    }

    /// Incremental query: refresh the index (cheap directory-level pass),
    /// replay cached verdicts, evaluate only the remainder in parallel
    /// across shards with `workers` threads.
    pub fn query(
        &mut self,
        ds: &BidsDataset,
        pipeline: &PipelineSpec,
        workers: usize,
    ) -> Result<(QueryResult, QueryStats)> {
        let new_keys = self.index.refresh(ds)?;

        let index = &self.index;
        let processed = &self.processed;
        let cache = self.skip_cache.get(pipeline.name);

        // Partition each shard into replays (answered from state) and
        // candidates (need evaluation).
        let mut result = QueryResult::default();
        let mut replayed = 0usize;
        let mut candidates: Vec<Vec<(&SessionKey, &crate::archive::SessionRecord)>> =
            vec![Vec::new(); index.n_shards()];
        for i in 0..index.n_shards() {
            for (key, rec) in index.shard(i) {
                if processed.contains(pipeline.name, key) {
                    result.skipped.push(SkipRecord {
                        subject: key.subject.clone(),
                        session: key.session.clone(),
                        reason: SkipReason::AlreadyProcessed,
                    });
                    replayed += 1;
                    continue;
                }
                if let Some(cached) = cache.and_then(|c| c.get(key)) {
                    if cached.valid(rec.generation, processed) {
                        if let Some(reason) = cached.to_reason() {
                            result.skipped.push(SkipRecord {
                                subject: key.subject.clone(),
                                session: key.session.clone(),
                                reason,
                            });
                            replayed += 1;
                            continue;
                        }
                    }
                }
                candidates[i].push((key, rec));
            }
        }

        // Parallel evaluation of the candidate sessions, shard by shard.
        let shard_jobs: Vec<_> = candidates
            .into_iter()
            .filter(|c| !c.is_empty())
            .map(|shard_candidates| {
                move || {
                    let mut runnable = Vec::new();
                    let mut skipped: Vec<(SessionKey, SkipReason, u64)> = Vec::new();
                    let mut absorbed: Vec<SessionKey> = Vec::new();
                    for (key, rec) in shard_candidates {
                        match evaluate_session(ds, pipeline, key, rec, processed) {
                            // Derivatives can predate the processed index
                            // (older runs, external writers): absorb after
                            // the probe so the next query replays from
                            // memory. (from_index can't occur here — the
                            // partition already filtered processed keys.)
                            SessionVerdict::AlreadyProcessed { from_index } => {
                                skipped.push((
                                    key.clone(),
                                    SkipReason::AlreadyProcessed,
                                    rec.generation,
                                ));
                                if !from_index {
                                    absorbed.push(key.clone());
                                }
                            }
                            SessionVerdict::Skip(reason) => {
                                skipped.push((key.clone(), reason, rec.generation))
                            }
                            SessionVerdict::Runnable(inputs) => {
                                runnable.push((key.clone(), job_for(ds, pipeline, key, inputs)))
                            }
                        }
                    }
                    (runnable, skipped, absorbed)
                }
            })
            .collect();

        let shards_scanned = shard_jobs.len();
        let shard_results = run_parallel(workers.max(1), shard_jobs);

        // Fold evaluation results back into the caches (sequentially).
        let mut examined = 0usize;
        let cache = self.skip_cache.entry(pipeline.name.to_string()).or_default();
        for (runnable, skipped, absorbed) in shard_results {
            for key in absorbed {
                self.processed.mark(pipeline.name, key);
            }
            for (key, job) in runnable {
                examined += 1;
                cache.remove(&key);
                result.runnable.push(job);
            }
            for (key, reason, generation) in skipped {
                examined += 1;
                if let Some(entry) = CachedSkip::from_reason(&reason, generation, &self.processed) {
                    cache.insert(key.clone(), entry);
                } else {
                    cache.remove(&key);
                }
                result.skipped.push(SkipRecord {
                    subject: key.subject.clone(),
                    session: key.session,
                    reason,
                });
            }
        }

        canonicalize(&mut result);
        let stats = QueryStats {
            full_scan: false,
            shards_scanned,
            sessions_examined: examined,
            sessions_replayed: replayed,
            new_sessions: new_keys.len(),
        };
        Ok((result, stats))
    }

    /// Record that `pipeline` completed `key` (the coordinator's copy-back
    /// hook). Bumps the pipeline's processed-set version, which is what
    /// re-examines sessions blocked on [`SkipReason::MissingPrior`].
    pub fn record_completion(&mut self, pipeline: &str, key: &SessionKey) {
        self.processed.mark(pipeline, key.clone());
        if let Some(cache) = self.skip_cache.get_mut(pipeline) {
            cache.remove(key);
        }
    }

    /// An empty engine that ignores any on-disk state — the recovery
    /// constructor when `.medflow/` is corrupt or torn (e.g. a crash
    /// between the meta and shard writes) and [`Self::open`] fails.
    /// Follow with [`Self::rebuild`]; the on-disk processed index is left
    /// untouched until explicitly saved over.
    pub fn fresh() -> Self {
        Self {
            index: EntityIndex::new(DEFAULT_SHARDS),
            processed: ProcessedIndex::default(),
            skip_cache: BTreeMap::new(),
            saved_index_generation: u64::MAX,
        }
    }

    /// Rebuild the entity index from a full walk and drop **every** cached
    /// skip verdict, persisting both. Required instead of a bare
    /// [`EntityIndex::build`] because a rebuilt index restarts its
    /// generation counter — stale cached verdicts stamped with old
    /// generations could otherwise collide with the new numbering and
    /// keep replaying outdated skips.
    pub fn rebuild(&mut self, ds: &BidsDataset) -> Result<()> {
        let mut index = EntityIndex::build(ds, DEFAULT_SHARDS)?;
        index.save_for(ds)?;
        self.saved_index_generation = index.generation;
        self.index = index;
        self.skip_cache.clear();
        save_skip_cache(&skip_cache_path(ds), &self.skip_cache)
    }

    /// Forget everything the engine believes about `pipeline` — required
    /// after its derivatives were written or deleted outside the engine.
    /// Drops its cached skip verdicts **and** its processed-set entries,
    /// and bumps its processed-set version so sessions other pipelines
    /// have cached as `MissingPrior(pipeline)` are re-examined too. The
    /// next query re-probes `derivatives/` for every affected session and
    /// re-absorbs whatever actually exists on disk.
    pub fn invalidate_pipeline(&mut self, pipeline: &str) {
        self.skip_cache.remove(pipeline);
        self.processed.reset(pipeline);
    }

    /// Cached-verdict count for a pipeline (telemetry/tests).
    pub fn cached_skips(&self, pipeline: &str) -> usize {
        self.skip_cache.get(pipeline).map_or(0, BTreeMap::len)
    }

    /// Persist all engine state under the dataset's index directory. The
    /// entity-index shards (the bulk of the state) are only rewritten when
    /// the index actually changed since the last open/save.
    pub fn save(&mut self, ds: &BidsDataset) -> Result<()> {
        if self.index.generation != self.saved_index_generation {
            self.index.save_for(ds)?;
            self.saved_index_generation = self.index.generation;
        }
        self.processed.save_for(ds)?;
        save_skip_cache(&skip_cache_path(ds), &self.skip_cache)
    }
}

/// Arrival-delta ledger for the streaming coordinator
/// (`coordinator::stream`, DESIGN.md §17) — the simulated-time
/// counterpart of [`IncrementalEngine`]'s on-disk delta query.
///
/// Where the engine answers "which sessions changed since the last
/// campaign" against a filesystem, the ledger answers "which sessions
/// *landed* since the last planning epoch" against a simulated arrival
/// process: sessions are ingested in arrival order, and each
/// [`poll`](Self::poll) drains exactly the sessions whose arrival
/// instant is ≤ the stream clock — O(delta) per epoch, like the engine.
/// Conservation is auditable at any instant: `arrived = drained +
/// pending`, and the stream loop folds its own processed/aborted split
/// back via [`record_completion`](Self::record_completion).
#[derive(Debug, Clone, Default)]
pub struct DeltaLedger {
    /// `(arrival_s, session id)` in non-decreasing arrival order.
    arrivals: Vec<(f64, u64)>,
    /// First not-yet-drained arrival.
    cursor: usize,
    /// Completions folded back by the consumer (telemetry only).
    completed: u64,
}

impl DeltaLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build a ledger from pre-sorted arrival instants; session ids are
    /// the positions `0..times.len()`.
    pub fn from_arrivals(times: &[f64]) -> Self {
        let mut ledger = Self::new();
        for (id, &t) in times.iter().enumerate() {
            ledger.ingest(t, id as u64);
        }
        ledger
    }

    /// Append one arrival. Arrivals must be fed in non-decreasing time
    /// order (the arrival generators sort before ingesting) — a
    /// time-travelling arrival would silently never drain once the
    /// cursor passed it, so it is rejected loudly instead.
    pub fn ingest(&mut self, arrival_s: f64, id: u64) {
        assert!(
            arrival_s.is_finite() && arrival_s >= 0.0,
            "DeltaLedger::ingest: arrival instant must be finite and ≥ 0 (got {arrival_s})"
        );
        if let Some(&(last, _)) = self.arrivals.last() {
            assert!(
                arrival_s >= last,
                "DeltaLedger::ingest: arrivals must be non-decreasing \
                 (got {arrival_s} after {last})"
            );
        }
        self.arrivals.push((arrival_s, id));
    }

    /// Drain every session whose arrival instant is ≤ `now_s`, in
    /// arrival order — the per-epoch delta the re-planning loop admits.
    pub fn poll(&mut self, now_s: f64) -> Vec<u64> {
        let start = self.cursor;
        while self.cursor < self.arrivals.len() && self.arrivals[self.cursor].0 <= now_s {
            self.cursor += 1;
        }
        self.arrivals[start..self.cursor].iter().map(|&(_, id)| id).collect()
    }

    /// Arrival instant of the next undrained session, if any — the
    /// stream loop uses it to jump idle gaps to the covering epoch
    /// boundary instead of spinning through empty epochs.
    pub fn next_arrival_s(&self) -> Option<f64> {
        self.arrivals.get(self.cursor).map(|&(t, _)| t)
    }

    /// Sessions ingested but not yet drained by a poll.
    pub fn pending(&self) -> usize {
        self.arrivals.len() - self.cursor
    }

    /// Sessions drained so far.
    pub fn drained(&self) -> usize {
        self.cursor
    }

    /// Fold `n` completions back (telemetry; mirrors
    /// [`IncrementalEngine::record_completion`]).
    pub fn record_completion(&mut self, n: u64) {
        self.completed += n;
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }
}

fn skip_cache_path(ds: &BidsDataset) -> std::path::PathBuf {
    ds.index_dir().join("skipcache.json")
}

fn save_skip_cache(
    path: &Path,
    cache: &BTreeMap<String, BTreeMap<SessionKey, CachedSkip>>,
) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut pipelines = Vec::new();
    for (pipeline, entries) in cache {
        let mut sessions = Vec::new();
        for (key, skip) in entries {
            let mut o = key.to_json();
            o.set("kind", Json::str(skip.kind()));
            if let CachedReason::MissingPrior(dep) = &skip.reason {
                o.set("dep", Json::str(dep));
            }
            o.set("generation", Json::num(skip.generation as f64));
            o.set("dep_version", Json::num(skip.dep_version as f64));
            sessions.push(Json::Obj(o));
        }
        let mut o = JsonObj::new();
        o.set("pipeline", Json::str(pipeline));
        o.set("sessions", Json::Arr(sessions));
        pipelines.push(Json::Obj(o));
    }
    let mut root = JsonObj::new();
    root.set("pipelines", Json::Arr(pipelines));
    std::fs::write(path, Json::Obj(root).to_string_pretty())?;
    Ok(())
}

fn load_skip_cache(path: &Path) -> Result<BTreeMap<String, BTreeMap<SessionKey, CachedSkip>>> {
    let mut out = BTreeMap::new();
    if !path.exists() {
        return Ok(out);
    }
    let json = Json::parse(
        &std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?,
    )?;
    for p in json.get_path("pipelines").and_then(Json::as_arr).unwrap_or(&[]) {
        let Some(name) = p.get_path("pipeline").and_then(Json::as_str) else {
            continue;
        };
        let mut entries = BTreeMap::new();
        for s in p.get_path("sessions").and_then(Json::as_arr).unwrap_or(&[]) {
            let Some(key) = SessionKey::from_json(s) else {
                continue;
            };
            let reason = match s.get_path("kind").and_then(Json::as_str) {
                Some("NoT1w") => CachedReason::NoT1w,
                Some("NoDwi") => CachedReason::NoDwi,
                Some("MissingPrior") => match s.get_path("dep").and_then(Json::as_str) {
                    Some(dep) => CachedReason::MissingPrior(dep.to_string()),
                    None => continue,
                },
                _ => continue,
            };
            entries.insert(
                key,
                CachedSkip {
                    reason,
                    generation: s.get_path("generation").and_then(Json::as_i64).unwrap_or(0) as u64,
                    dep_version: s.get_path("dep_version").and_then(Json::as_i64).unwrap_or(0)
                        as u64,
                },
            );
        }
        out.insert(name.to_string(), entries);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bids::{BidsName, Modality};
    use crate::query::find_runnable;

    fn tmpds(tag: &str) -> BidsDataset {
        let parent =
            std::env::temp_dir().join(format!("medflow_inc_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&parent).unwrap();
        BidsDataset::create(&parent, "DS").unwrap()
    }

    fn add_image(ds: &BidsDataset, sub: &str, ses: Option<&str>, m: Modality) {
        let name = BidsName::new(sub, ses, m);
        let p = ds.raw_path(&name, "nii.gz");
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(&p, b"img").unwrap();
    }

    fn cleanup(ds: &BidsDataset) {
        std::fs::remove_dir_all(ds.root.parent().unwrap()).unwrap();
    }

    #[test]
    fn engine_matches_full_scan_on_first_query() {
        let ds = tmpds("parity");
        add_image(&ds, "01", Some("a"), Modality::T1w);
        add_image(&ds, "02", Some("a"), Modality::Dwi);
        add_image(&ds, "03", None, Modality::T1w);
        let fs = by_name("freesurfer").unwrap();
        let full = find_runnable(&ds, &fs).unwrap();
        let mut engine = IncrementalEngine::open(&ds).unwrap();
        let (inc, stats) = engine.query(&ds, &fs, 4).unwrap();
        assert_eq!(inc.runnable, full.runnable);
        assert_eq!(inc.skipped, full.skipped);
        assert!(!stats.full_scan);
        assert_eq!(stats.sessions_examined, 3);
        assert_eq!(stats.sessions_replayed, 0);
        cleanup(&ds);
    }

    #[test]
    fn second_query_replays_everything() {
        let ds = tmpds("replay");
        add_image(&ds, "01", Some("a"), Modality::T1w);
        add_image(&ds, "02", Some("a"), Modality::Dwi);
        let fs = by_name("freesurfer").unwrap();
        let mut engine = IncrementalEngine::open(&ds).unwrap();
        let (r1, _) = engine.query(&ds, &fs, 2).unwrap();
        assert_eq!(r1.runnable.len(), 1);
        for job in &r1.runnable {
            let key = SessionKey::new(&job.subject, job.session.as_deref());
            engine.record_completion("freesurfer", &key);
        }
        let (r2, stats) = engine.query(&ds, &fs, 2).unwrap();
        assert!(r2.runnable.is_empty());
        assert_eq!(r2.skipped.len(), 2);
        assert_eq!(stats.sessions_examined, 0, "nothing changed — no evaluation");
        assert_eq!(stats.sessions_replayed, 2);
        cleanup(&ds);
    }

    #[test]
    fn persistence_survives_reopen() {
        let ds = tmpds("reopen");
        add_image(&ds, "01", None, Modality::T1w);
        add_image(&ds, "02", None, Modality::Dwi);
        let fs = by_name("freesurfer").unwrap();
        {
            let mut engine = IncrementalEngine::open(&ds).unwrap();
            let (r, _) = engine.query(&ds, &fs, 2).unwrap();
            assert_eq!(r.runnable.len(), 1);
            engine.record_completion("freesurfer", &SessionKey::new("01", None));
            engine.save(&ds).unwrap();
        }
        // a fresh process opens the same state: zero evaluations
        let mut engine = IncrementalEngine::open(&ds).unwrap();
        let (r, stats) = engine.query(&ds, &fs, 2).unwrap();
        assert!(r.runnable.is_empty());
        assert_eq!(stats.sessions_examined, 0);
        assert_eq!(stats.sessions_replayed, 2);
        cleanup(&ds);
    }

    #[test]
    fn missing_prior_unblocks_on_version_bump() {
        let ds = tmpds("unblock");
        add_image(&ds, "01", None, Modality::Dwi);
        let ts = by_name("tractseg").unwrap();
        let mut engine = IncrementalEngine::open(&ds).unwrap();
        let (r1, _) = engine.query(&ds, &ts, 2).unwrap();
        assert!(r1.runnable.is_empty());
        assert_eq!(r1.skipped[0].reason, SkipReason::MissingPrior("prequal"));
        // replayed from cache while prequal hasn't progressed
        let (_, s2) = engine.query(&ds, &ts, 2).unwrap();
        assert_eq!(s2.sessions_examined, 0);
        // prequal completes → version bump → exactly this session re-examined
        engine.record_completion("prequal", &SessionKey::new("01", None));
        let (r3, s3) = engine.query(&ds, &ts, 2).unwrap();
        assert_eq!(s3.sessions_examined, 1);
        assert_eq!(r3.runnable.len(), 1, "session unblocked");
        cleanup(&ds);
    }

    #[test]
    fn new_session_found_incrementally() {
        let ds = tmpds("delta");
        add_image(&ds, "01", Some("a"), Modality::T1w);
        let fs = by_name("freesurfer").unwrap();
        let mut engine = IncrementalEngine::open(&ds).unwrap();
        let (r1, _) = engine.query(&ds, &fs, 2).unwrap();
        assert_eq!(r1.runnable.len(), 1);
        engine.record_completion("freesurfer", &SessionKey::new("01", Some("a")));
        add_image(&ds, "02", Some("b"), Modality::T1w);
        let (r, stats) = engine.query(&ds, &fs, 2).unwrap();
        assert_eq!(stats.new_sessions, 1);
        assert_eq!(stats.sessions_examined, 1, "only the new session");
        assert!(r.runnable.iter().any(|j| j.subject == "02"));
        cleanup(&ds);
    }

    #[test]
    fn changed_session_reevaluated_via_generation() {
        let ds = tmpds("gen");
        add_image(&ds, "01", None, Modality::T1w);
        let cs = by_name("connectome_special").unwrap(); // needs T1w + DWI
        let mut engine = IncrementalEngine::open(&ds).unwrap();
        let (r1, _) = engine.query(&ds, &cs, 2).unwrap();
        assert_eq!(r1.skipped[0].reason, SkipReason::NoDwi);
        // DWI arrives later; the ingest path re-records the session
        add_image(&ds, "01", None, Modality::Dwi);
        let key = SessionKey::new("01", None);
        engine.index.record_session(&ds, &key);
        let (r2, stats) = engine.query(&ds, &cs, 2).unwrap();
        assert_eq!(stats.sessions_examined, 1);
        assert_eq!(r2.runnable.len(), 1);
        assert_eq!(r2.runnable[0].inputs.len(), 2);
        cleanup(&ds);
    }

    #[test]
    fn rebuild_clears_stale_verdicts() {
        let ds = tmpds("rebuild");
        add_image(&ds, "01", None, Modality::T1w);
        let cs = by_name("connectome_special").unwrap();
        let mut engine = IncrementalEngine::open(&ds).unwrap();
        let (r1, _) = engine.query(&ds, &cs, 2).unwrap();
        assert_eq!(r1.skipped[0].reason, SkipReason::NoDwi);
        assert_eq!(engine.cached_skips("connectome_special"), 1);
        // DWI appears out-of-band; the operator rebuilds. A rebuilt index
        // restarts generations, so stale verdicts MUST not survive it.
        add_image(&ds, "01", None, Modality::Dwi);
        engine.rebuild(&ds).unwrap();
        assert_eq!(engine.cached_skips("connectome_special"), 0);
        let (r2, _) = engine.query(&ds, &cs, 2).unwrap();
        assert_eq!(r2.runnable.len(), 1);
        cleanup(&ds);
    }

    #[test]
    fn invalidate_pipeline_recovers_from_out_of_band_changes() {
        let ds = tmpds("invalidate");
        add_image(&ds, "01", None, Modality::Dwi);
        let ts = by_name("tractseg").unwrap();
        let mut engine = IncrementalEngine::open(&ds).unwrap();
        // blocked on prequal, verdict cached
        let (r1, _) = engine.query(&ds, &ts, 2).unwrap();
        assert_eq!(r1.skipped[0].reason, SkipReason::MissingPrior("prequal"));
        // prequal outputs appear OUTSIDE the engine (older tooling)
        let name = BidsName::new("01", None, Modality::T1w);
        let d = ds.derivative_dir("prequal", &name);
        std::fs::create_dir_all(&d).unwrap();
        std::fs::write(d.join("out.txt"), b"done").unwrap();
        // without invalidation the stale MissingPrior verdict replays...
        let (r2, s2) = engine.query(&ds, &ts, 2).unwrap();
        assert!(r2.runnable.is_empty());
        assert_eq!(s2.sessions_examined, 0);
        // ...invalidate_pipeline bumps prequal's version, so the blocked
        // session re-examines, probes derivatives/, and unblocks
        engine.invalidate_pipeline("prequal");
        let (r3, s3) = engine.query(&ds, &ts, 2).unwrap();
        assert_eq!(s3.sessions_examined, 1);
        assert_eq!(r3.runnable.len(), 1);
        cleanup(&ds);
    }

    #[test]
    fn ledger_polls_exactly_the_arrived_delta() {
        let mut ledger = DeltaLedger::from_arrivals(&[0.0, 10.0, 10.0, 25.0]);
        assert_eq!(ledger.pending(), 4);
        assert_eq!(ledger.next_arrival_s(), Some(0.0));
        assert_eq!(ledger.poll(10.0), vec![0, 1, 2]);
        assert_eq!(ledger.pending(), 1);
        assert_eq!(ledger.drained(), 3);
        // re-polling the same instant drains nothing (delta, not scan)
        assert!(ledger.poll(10.0).is_empty());
        assert_eq!(ledger.next_arrival_s(), Some(25.0));
        assert_eq!(ledger.poll(1e9), vec![3]);
        assert_eq!(ledger.pending(), 0);
        assert_eq!(ledger.next_arrival_s(), None);
        ledger.record_completion(4);
        assert_eq!(ledger.completed(), 4);
    }

    #[test]
    #[should_panic(expected = "non-decreasing")]
    fn ledger_rejects_time_travelling_arrivals() {
        let mut ledger = DeltaLedger::new();
        ledger.ingest(5.0, 0);
        ledger.ingest(4.0, 1);
    }

    #[test]
    fn external_derivatives_absorbed_into_processed_index() {
        let ds = tmpds("absorb");
        add_image(&ds, "01", None, Modality::T1w);
        // a pre-engine campaign left outputs on disk but no processed index
        let name = BidsName::new("01", None, Modality::T1w);
        let d = ds.derivative_dir("freesurfer", &name);
        std::fs::create_dir_all(&d).unwrap();
        std::fs::write(d.join("out.txt"), b"done").unwrap();
        let fs = by_name("freesurfer").unwrap();
        let mut engine = IncrementalEngine::open(&ds).unwrap();
        let (r1, s1) = engine.query(&ds, &fs, 2).unwrap();
        assert!(r1.runnable.is_empty());
        assert_eq!(r1.skipped[0].reason, SkipReason::AlreadyProcessed);
        assert_eq!(s1.sessions_examined, 1, "probed once");
        // absorbed: second query replays from the processed index
        let (_, s2) = engine.query(&ds, &fs, 2).unwrap();
        assert_eq!(s2.sessions_examined, 0);
        assert!(engine.processed.contains("freesurfer", &SessionKey::new("01", None)));
        cleanup(&ds);
    }
}
