//! Deployment configuration: one JSON file describing the whole
//! installation (storage servers, cluster shape, pricing overrides,
//! enabled pipelines, campaign defaults) so a site can adapt medflow
//! without recompiling — the paper's "consider whether the options
//! available to you would be similarly cost-effective" (§4), made
//! concrete.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::slurm::ClusterSpec;
use crate::util::json::{Json, JsonObj};

/// Site-wide configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteConfig {
    pub site_name: String,
    /// Root under which store/, bids/, containers/ live.
    pub root: PathBuf,
    /// Cluster shape (nodes, cores/node, ram GB/node).
    pub cluster_nodes: usize,
    pub cluster_cores_per_node: u32,
    pub cluster_ram_gb_per_node: u32,
    /// Pipelines enabled at this site (empty = all).
    pub enabled_pipelines: Vec<String>,
    /// Campaign defaults.
    pub default_user: String,
    pub max_concurrent_array: u32,
    pub local_burst_workers: usize,
}

impl Default for SiteConfig {
    fn default() -> Self {
        Self {
            site_name: "vanderbilt-accre".into(),
            root: PathBuf::from("/data/medflow"),
            cluster_nodes: 750,
            cluster_cores_per_node: 27,
            cluster_ram_gb_per_node: 267,
            enabled_pipelines: Vec::new(),
            default_user: "medflow".into(),
            max_concurrent_array: 200,
            local_burst_workers: 8,
        }
    }
}

impl SiteConfig {
    pub fn cluster_spec(&self) -> ClusterSpec {
        ClusterSpec {
            name: self.site_name.clone(),
            nodes: vec![
                crate::slurm::NodeSpec {
                    cores: self.cluster_cores_per_node,
                    ram_gb: self.cluster_ram_gb_per_node,
                };
                self.cluster_nodes
            ],
        }
    }

    pub fn pipeline_enabled(&self, name: &str) -> bool {
        self.enabled_pipelines.is_empty() || self.enabled_pipelines.iter().any(|p| p == name)
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.set("SiteName", Json::str(&self.site_name));
        o.set("Root", Json::str(self.root.to_string_lossy()));
        let mut cluster = JsonObj::new();
        cluster.set("Nodes", Json::num(self.cluster_nodes as f64));
        cluster.set("CoresPerNode", Json::num(self.cluster_cores_per_node as f64));
        cluster.set("RamGbPerNode", Json::num(self.cluster_ram_gb_per_node as f64));
        o.set("Cluster", Json::Obj(cluster));
        o.set(
            "EnabledPipelines",
            Json::Arr(self.enabled_pipelines.iter().map(Json::str).collect()),
        );
        let mut campaign = JsonObj::new();
        campaign.set("DefaultUser", Json::str(&self.default_user));
        campaign.set("MaxConcurrentArray", Json::num(self.max_concurrent_array as f64));
        campaign.set("LocalBurstWorkers", Json::num(self.local_burst_workers as f64));
        o.set("Campaign", Json::Obj(campaign));
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = SiteConfig::default();
        if let Some(v) = j.get_path("SiteName").and_then(Json::as_str) {
            cfg.site_name = v.to_string();
        }
        if let Some(v) = j.get_path("Root").and_then(Json::as_str) {
            cfg.root = PathBuf::from(v);
        }
        if let Some(v) = j.get_path("Cluster.Nodes").and_then(Json::as_i64) {
            if v <= 0 {
                bail!("Cluster.Nodes must be positive");
            }
            cfg.cluster_nodes = v as usize;
        }
        if let Some(v) = j.get_path("Cluster.CoresPerNode").and_then(Json::as_i64) {
            if v <= 0 {
                bail!("Cluster.CoresPerNode must be positive");
            }
            cfg.cluster_cores_per_node = v as u32;
        }
        if let Some(v) = j.get_path("Cluster.RamGbPerNode").and_then(Json::as_i64) {
            cfg.cluster_ram_gb_per_node = v as u32;
        }
        if let Some(arr) = j.get_path("EnabledPipelines").and_then(Json::as_arr) {
            cfg.enabled_pipelines = arr.iter().filter_map(Json::as_str).map(String::from).collect();
            for p in &cfg.enabled_pipelines {
                if crate::pipeline::by_name(p).is_none() {
                    bail!("EnabledPipelines lists unknown pipeline '{p}'");
                }
            }
        }
        if let Some(v) = j.get_path("Campaign.DefaultUser").and_then(Json::as_str) {
            cfg.default_user = v.to_string();
        }
        if let Some(v) = j.get_path("Campaign.MaxConcurrentArray").and_then(Json::as_i64) {
            cfg.max_concurrent_array = v as u32;
        }
        if let Some(v) = j.get_path("Campaign.LocalBurstWorkers").and_then(Json::as_i64) {
            cfg.local_burst_workers = v as usize;
        }
        Ok(cfg)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_accre() {
        let c = SiteConfig::default();
        let spec = c.cluster_spec();
        assert_eq!(spec.nodes.len(), 750);
        assert_eq!(spec.total_cores(), 750 * 27);
        assert!(c.pipeline_enabled("freesurfer")); // empty list = all
    }

    #[test]
    fn json_roundtrip() {
        let mut c = SiteConfig::default();
        c.site_name = "other-hpc".into();
        c.cluster_nodes = 12;
        c.enabled_pipelines = vec!["freesurfer".into(), "prequal".into()];
        let back = SiteConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
        assert!(back.pipeline_enabled("prequal"));
        assert!(!back.pipeline_enabled("slant"));
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("medflow_cfg_{}", std::process::id()));
        let path = dir.join("site.json");
        let c = SiteConfig::default();
        c.save(&path).unwrap();
        assert_eq!(SiteConfig::load(&path).unwrap(), c);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = Json::parse(r#"{"SiteName": "tiny", "Cluster": {"Nodes": 4}}"#).unwrap();
        let c = SiteConfig::from_json(&j).unwrap();
        assert_eq!(c.site_name, "tiny");
        assert_eq!(c.cluster_nodes, 4);
        assert_eq!(c.cluster_cores_per_node, 27); // default retained
    }

    #[test]
    fn rejects_bad_values() {
        let j = Json::parse(r#"{"Cluster": {"Nodes": 0}}"#).unwrap();
        assert!(SiteConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"EnabledPipelines": ["not_a_pipeline"]}"#).unwrap();
        assert!(SiteConfig::from_json(&j).is_err());
    }
}
